//! ProofScope — a static stall verifier for generated kernels.
//!
//! StallScope (`profile::StallClass`) *measures* where cycles go;
//! ProofScope *proves*, before a single cycle is simulated, which
//! stall classes cannot occur for a given plan. The paper's headline
//! claims — zero-overhead loop nests and a conflict-free
//! double-buffered memory subsystem — are static properties of the
//! generated program + cluster configuration, so they are stated here
//! as machine-checked verdicts and theorems rather than observations.
//!
//! The analyzer runs an abstract interpretation over the decoded
//! instruction streams of all nine cores (8 compute + DM):
//!
//! * **Constant propagation** over the integer register file
//!   (`Val::Known | Dmstat | Unknown`). Generated kernels compute
//!   every address and loop bound from immediates, so the walk stays
//!   fully concrete; anything else degrades to `Unknown` verdicts
//!   instead of unsound claims.
//! * **SSR stride lattices**: `scfgw` writes are tracked per stream,
//!   and every `ReadBase`/`WriteBase` arming snapshots the full
//!   geometry. The exact element-address footprint is recovered with
//!   the same odometer the streamer hardware implements
//!   (`ssr::oracle_addresses`).
//! * **DMA descriptors**: `dmsrc/dmdst/dmstr[2]/dmrep[2]/dmcpy`
//!   rebuild the 3-D descriptor; its TCDM-side beat addresses are
//!   enumerated beat by beat.
//! * **Barrier segmentation**: every address is tagged with the
//!   barrier segment it can fly in. Barriers release globally, so
//!   traffic from segment `s` of one core can only ever be concurrent
//!   with segment `s` of another — that temporal argument is what
//!   turns per-segment set disjointness into a race/conflict proof.
//!
//! Verdict semantics (checked by the differential gate):
//!
//! * `Impossible`  — measured stall cycles for the class must be 0.
//! * `Bounded(n)`  — measured stall cycles must be `<= n`.
//! * `Unknown`     — no claim.
//!
//! The bounds are sound but deliberately loose (round-robin fairness
//! worst cases); their value is that they are *claims*, so a
//! regression that turns a bounded class pathological fails CI.
//!
//! ProofScope also subsumes FastPath's region-safety scan:
//! [`dm_program_region_safe`] lives here and `cluster::Cluster` calls
//! it for its fast-forward gate, so fast-forwarding and the published
//! verdicts rest on one soundness story (see DESIGN.md §13).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use crate::cluster::{ClusterConfig, ConfigId};
use crate::isa::{csr, decode::decode, Instr, Program, SsrField};
use crate::mem::{Tcdm, BANKS_PER_SUPERBANK};
use crate::profile::{StallClass, N_CLASSES};
use crate::ssr::oracle_addresses;

/// Abstract-interpretation step budget per program (a generated
/// program executes a few thousand frontend slots; this is a runaway
/// guard, not a tuning knob).
const FUEL: u64 = 32_000_000;

/// Slack cycles granted to the whole-cluster control-overhead bound:
/// covers the handful of start-up cycles (reset skew, first fetch)
/// that belong to no instruction.
const CTRL_SLACK: u64 = 64;

/// Per-resolved-poll control-overhead allowance: the final poll
/// iterations that straddle DMA completion (dmstat + untaken bne plus
/// one taken-loop tail) run with the engine already idle.
const CTRL_PER_POLL: u64 = 8;

// ------------------------------------------------------------------
// Public report types
// ------------------------------------------------------------------

/// Static claim about one StallScope class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// The class cannot receive a single cycle.
    Impossible,
    /// The class receives at most this many core-cycles, summed over
    /// every core of every cluster the plan runs on.
    Bounded(u64),
    /// No claim.
    Unknown,
}

impl Verdict {
    pub fn name(&self) -> &'static str {
        match self {
            Verdict::Impossible => "impossible",
            Verdict::Bounded(_) => "bounded",
            Verdict::Unknown => "unknown",
        }
    }

    /// The bound as a CSV cell ("" when the verdict carries none).
    pub fn bound_str(&self) -> String {
        match self {
            Verdict::Bounded(n) => n.to_string(),
            _ => String::new(),
        }
    }
}

/// A named structural fact the analyzer either established or could
/// not establish for this plan.
#[derive(Clone, Debug)]
pub struct Theorem {
    pub name: &'static str,
    pub holds: bool,
    pub detail: String,
}

/// The analyzer's output: one verdict per StallScope class plus the
/// supporting theorems.
#[derive(Clone, Debug)]
pub struct StaticStallReport {
    pub config: ConfigId,
    /// Clusters the verdicts are scaled to (bounds are per-fabric).
    pub clusters: usize,
    pub verdicts: [Verdict; N_CLASSES],
    pub theorems: Vec<Theorem>,
    /// Free-form analysis notes (why something stayed `Unknown`).
    pub notes: Vec<String>,
}

/// Theorem names (stable identifiers — pinned by the lint CSV golden).
pub mod theorem {
    /// All nine programs execute the same number of barriers and halt.
    pub const BARRIERS_MATCHED: &str = "barriers_matched";
    /// Every SSR element and DMA beat lands inside TCDM.
    pub const CAPACITY_OK: &str = "capacity_ok";
    /// Per segment, DMA superbanks and compute-SSR superbanks are
    /// disjoint: the interconnect can never arbitrate a DMA beat
    /// against a stream request (the paper's Dobu claim).
    pub const DMA_PHASE_DISJOINT: &str = "dma_phase_disjoint";
    /// Per segment, DMA words and SSR words are disjoint: the double
    /// buffer has no read/write race regardless of cycle timing.
    pub const DOUBLE_BUFFER_RACE_FREE: &str = "double_buffer_race_free";
    /// The DM program passes the FastPath region-safety scan.
    pub const REGION_SAFETY: &str = "region_safety";
    /// Compute programs are branch-free: the loop nest runs entirely
    /// from the FREP sequencer (zero-overhead loop nests).
    pub const ZONL_ZERO_LOOP_OVERHEAD: &str = "zonl_zero_loop_overhead";
}

impl StaticStallReport {
    /// All-`Unknown` report (analysis bailed); `note` says why.
    pub fn unknown(
        config: ConfigId,
        clusters: usize,
        note: String,
    ) -> StaticStallReport {
        StaticStallReport {
            config,
            clusters,
            verdicts: [Verdict::Unknown; N_CLASSES],
            theorems: Vec::new(),
            notes: vec![note],
        }
    }

    pub fn verdict(&self, c: StallClass) -> Verdict {
        self.verdicts[c as usize]
    }

    pub fn theorem(&self, name: &str) -> Option<&Theorem> {
        self.theorems.iter().find(|t| t.name == name)
    }

    /// Re-scale a single-cluster report to an `n`-cluster fabric run:
    /// bounds multiply (every cluster runs the same shard plan), and
    /// the single-cluster `NocGated = Impossible` claim — which rests
    /// on the lone crossbar always granting — is withdrawn.
    pub fn for_clusters(&self, n: usize) -> StaticStallReport {
        let n = n.max(1);
        let mut r = self.clone();
        r.clusters = n;
        if n == 1 {
            return r;
        }
        for v in r.verdicts.iter_mut() {
            if let Verdict::Bounded(b) = v {
                *v = Verdict::Bounded(b.saturating_mul(n as u64));
            }
        }
        if r.verdicts[StallClass::NocGated as usize] == Verdict::Impossible
        {
            r.verdicts[StallClass::NocGated as usize] = Verdict::Unknown;
            r.notes.push(format!(
                "noc_gated: impossible only single-cluster; {n} clusters \
                 share a NoC"
            ));
        }
        r
    }

    /// Downgrade `Bounded` claims to `Unknown`, keeping only the
    /// `Impossible` ones. The prediction-tier gate for the analytic
    /// backend: its stall decomposition approximates magnitudes, so
    /// bound checks are meaningful against the cycle engine only,
    /// while an `Impossible` class must be absent from any faithful
    /// prediction too.
    pub fn impossible_only(&self) -> StaticStallReport {
        let mut r = self.clone();
        for v in r.verdicts.iter_mut() {
            if matches!(v, Verdict::Bounded(_)) {
                *v = Verdict::Unknown;
            }
        }
        r
    }

    /// The differential soundness gate: check measured per-class stall
    /// cycles (summed over every core) against the verdicts. Returns
    /// one message per violation (empty = gate passes).
    pub fn gate(
        &self,
        source: &str,
        measured: &[u64; N_CLASSES],
    ) -> Vec<String> {
        let mut fails = Vec::new();
        for c in StallClass::all() {
            let m = measured[c as usize];
            match self.verdicts[c as usize] {
                Verdict::Impossible if m > 0 => fails.push(format!(
                    "{source}: {} proved impossible but measured {m} \
                     stall cycles",
                    c.name()
                )),
                Verdict::Bounded(b) if m > b => fails.push(format!(
                    "{source}: {} bounded at {b} but measured {m} \
                     stall cycles",
                    c.name()
                )),
                _ => {}
            }
        }
        fails
    }

    /// The DMA facet of the gate: when the phase-disjointness theorem
    /// holds, the interconnect must have arbitrated zero DMA-vs-core
    /// conflicts.
    pub fn gate_dma(
        &self,
        source: &str,
        tcdm_conflicts_dma: u64,
    ) -> Option<String> {
        match self.theorem(theorem::DMA_PHASE_DISJOINT) {
            Some(t) if t.holds && tcdm_conflicts_dma > 0 => Some(format!(
                "{source}: dma_phase_disjoint proved but interconnect \
                 counted {tcdm_conflicts_dma} DMA-vs-core conflicts"
            )),
            _ => None,
        }
    }
}

/// Measured stall cycles per class, summed over every core of a
/// profile — the quantity the differential gate holds to the static
/// verdicts.
pub fn class_totals(
    profile: &crate::profile::StallProfile,
) -> [u64; N_CLASSES] {
    let mut t = [0u64; N_CLASSES];
    for core in &profile.per_core {
        for (i, v) in core.counts.iter().enumerate() {
            t[i] += v;
        }
    }
    t
}

// ------------------------------------------------------------------
// FastPath region safety (moved here from `cluster` — one soundness
// story for fast-forwarding and the published verdicts)
// ------------------------------------------------------------------

/// A DM-core program is *region-safe* when it can never touch the FP
/// subsystem or the SSR streamers: no FP compute, no FREP, no FP
/// loads/stores or converts, no SSR configuration, no SSR-enable CSR
/// toggles. Such a program's only TCDM traffic is its integer LSU,
/// which the region step arbitrates for real — so specializing the
/// compute cores away cannot change any arbitration outcome.
pub fn dm_program_region_safe(p: &Program) -> bool {
    p.instrs.iter().all(|i| {
        if i.is_fp_compute() {
            return false;
        }
        match i {
            Instr::Frep { .. }
            | Instr::Fld { .. }
            | Instr::Fsd { .. }
            | Instr::FcvtDW { .. }
            | Instr::SsrCfgW { .. } => false,
            Instr::Csrrw { csr: c, .. }
            | Instr::Csrrs { csr: c, .. }
            | Instr::Csrrsi { csr: c, .. }
            | Instr::Csrrci { csr: c, .. } => *c != csr::SSR_ENABLE,
            _ => true,
        }
    })
}

// ------------------------------------------------------------------
// Abstract interpreter
// ------------------------------------------------------------------

/// Abstract integer value: generated programs are fully constant, so
/// the lattice needs only "known", "a dmstat poll result", and "gave
/// up".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Val {
    Known(u32),
    /// Result of `dmstat`: the in-flight transfer count. Only ever
    /// consumed by the canonical `bne rd, x0, poll` loop.
    Dmstat,
    Unknown,
}

/// SSR stream geometry as configured by `scfgw` writes.
#[derive(Clone, Copy, Debug, Default)]
struct SsrGeom {
    bounds: [u32; 4],
    strides: [u32; 4],
}

/// A stream arming (`ReadBase`/`WriteBase`): base + the geometry
/// snapshot taken at arming time, exactly what the streamer latches.
#[derive(Clone, Copy, Debug)]
struct Arming {
    base: u32,
    /// Active dimensions (`d+1` for `ReadBase(d)`).
    dims: usize,
    geom: SsrGeom,
}

impl Arming {
    /// Total element requests this arming issues when streamed to
    /// exhaustion (the repeat field serves FIFO pops, not requests).
    fn elements(&self) -> u64 {
        self.geom.bounds[..self.dims]
            .iter()
            .map(|&b| b as u64 + 1)
            .product()
    }

    /// Odometer parameters for the element-address footprint.
    /// Dimensions with stride 0 only repeat addresses, so they are
    /// dropped before enumeration — the address *set* is identical
    /// and the walk stays small.
    fn enum_params(&self) -> (Vec<u32>, Vec<i32>) {
        let mut bounds = Vec::new();
        let mut strides = Vec::new();
        for d in 0..self.dims {
            if self.geom.strides[d] != 0 {
                bounds.push(self.geom.bounds[d] + 1);
                strides.push(self.geom.strides[d] as i32);
            }
        }
        (bounds, strides)
    }
}

/// One launched DMA descriptor, tagged with the barrier segment it
/// was issued (and, by the wait-before-barrier discipline, completes)
/// in.
#[derive(Clone, Copy, Debug)]
struct DmaXfer {
    src: u32,
    dst: u32,
    size: u32,
    src_stride: u32,
    dst_stride: u32,
    reps: u32,
    src_stride2: u32,
    dst_stride2: u32,
    reps2: u32,
    segment: usize,
}

/// 8-byte beat addresses of one side of a DMA descriptor.
fn dma_side_addrs(
    base: u32,
    size: u32,
    s1: u32,
    reps: u32,
    s2: u32,
    reps2: u32,
) -> Vec<u32> {
    let mut out = Vec::new();
    for r2 in 0..reps2 {
        for r1 in 0..reps {
            let row = base
                .wrapping_add(r2.wrapping_mul(s2))
                .wrapping_add(r1.wrapping_mul(s1));
            let mut off = 0;
            while off < size {
                out.push(row.wrapping_add(off));
                off += 8;
            }
        }
    }
    out
}

/// Everything the abstract walk learned about one program.
#[derive(Clone, Debug, Default)]
struct Facts {
    /// Frontend issue slots executed (each instruction once per
    /// dynamic execution; FP ops count their single offload slot).
    executions: u64,
    taken_branches: u64,
    /// Resolved `dmstat`-poll loops.
    polls: u64,
    barriers: usize,
    /// Drain points: `csrrci ssr_enable` and `fsd` executions.
    drain_points: u64,
    /// Integer-LSU traffic present (`lw/sw/fld/fsd`) — degrades the
    /// bank-conflict and control-overhead claims to `Unknown`.
    has_lsu: bool,
    halted: bool,
    /// Every barrier (and the halt) was reached with zero in-flight
    /// DMA transfers — the wait-before-barrier discipline that pins
    /// DMA traffic inside its issuing segment.
    wait_aligned: bool,
    /// `(segment, arming)` for every stream armed at an `ssr_enable`.
    uses: Vec<(usize, Arming)>,
    dmas: Vec<DmaXfer>,
    /// Total SSR element requests across all uses (with stride-0
    /// repetition dimensions counted — each element is a request).
    ssr_elements: u64,
}

/// DMA staging registers mirrored from the frontend.
#[derive(Clone, Copy, Debug)]
struct DmaRegs {
    src: u32,
    dst: u32,
    src_stride: u32,
    dst_stride: u32,
    reps: u32,
    src_stride2: u32,
    dst_stride2: u32,
    reps2: u32,
}

impl Default for DmaRegs {
    fn default() -> Self {
        DmaRegs {
            src: 0,
            dst: 0,
            src_stride: 0,
            dst_stride: 0,
            reps: 1,
            src_stride2: 0,
            dst_stride2: 0,
            reps2: 1,
        }
    }
}

fn known(v: Val) -> Option<u32> {
    match v {
        Val::Known(x) => Some(x),
        _ => None,
    }
}

/// Abstractly execute one program. `Err` means the program left the
/// fragment the analyzer models concretely — the caller degrades to
/// `Unknown`, never to an unsound claim.
fn walk(p: &Program) -> Result<Facts, String> {
    let mut f = Facts { wait_aligned: true, ..Facts::default() };
    let mut regs = [Val::Known(0); 32];
    let mut geom = [SsrGeom::default(); 4];
    let mut armed: [Option<Arming>; 4] = [None; 4];
    let mut dma = DmaRegs::default();
    let mut in_flight: u32 = 0;
    let mut segment = 0usize;
    let mut pc = 0usize;
    let mut fuel = FUEL;

    let rd_val = |regs: &[Val; 32], r: u8| {
        if r == 0 {
            Val::Known(0)
        } else {
            regs[r as usize]
        }
    };
    let need = |regs: &[Val; 32], r: u8, what: &str| {
        known(rd_val(regs, r))
            .ok_or_else(|| format!("{what} reads non-constant x{r}"))
    };
    let set = |regs: &mut [Val; 32], r: u8, v: Val| {
        if r != 0 {
            regs[r as usize] = v;
        }
    };

    loop {
        if fuel == 0 {
            return Err("fuel exhausted (runaway loop?)".into());
        }
        fuel -= 1;
        let Some(&i) = p.instrs.get(pc) else {
            return Err(format!("pc {pc} ran off the end"));
        };
        f.executions += 1;
        let mut next = pc + 1;
        match i {
            Instr::Lui { rd, imm } => {
                set(&mut regs, rd, Val::Known(imm as u32));
            }
            Instr::Auipc { rd, .. } => {
                set(&mut regs, rd, Val::Unknown);
            }
            Instr::Addi { rd, rs1, imm } => {
                let v = match known(rd_val(&regs, rs1)) {
                    Some(x) => Val::Known(x.wrapping_add(imm as u32)),
                    None => Val::Unknown,
                };
                set(&mut regs, rd, v);
            }
            Instr::Slli { rd, rs1, shamt } => {
                let v = match known(rd_val(&regs, rs1)) {
                    Some(x) => Val::Known(x.wrapping_shl(shamt as u32)),
                    None => Val::Unknown,
                };
                set(&mut regs, rd, v);
            }
            Instr::Srli { rd, rs1, shamt } => {
                let v = match known(rd_val(&regs, rs1)) {
                    Some(x) => Val::Known(x.wrapping_shr(shamt as u32)),
                    None => Val::Unknown,
                };
                set(&mut regs, rd, v);
            }
            Instr::Andi { rd, rs1, imm } => {
                let v = match known(rd_val(&regs, rs1)) {
                    Some(x) => Val::Known(x & imm as u32),
                    None => Val::Unknown,
                };
                set(&mut regs, rd, v);
            }
            Instr::Add { rd, rs1, rs2 }
            | Instr::Sub { rd, rs1, rs2 }
            | Instr::Mul { rd, rs1, rs2 } => {
                let a = known(rd_val(&regs, rs1));
                let b = known(rd_val(&regs, rs2));
                let v = match (a, b) {
                    (Some(a), Some(b)) => Val::Known(match i {
                        Instr::Add { .. } => a.wrapping_add(b),
                        Instr::Sub { .. } => a.wrapping_sub(b),
                        _ => a.wrapping_mul(b),
                    }),
                    _ => Val::Unknown,
                };
                set(&mut regs, rd, v);
            }
            Instr::Beq { rs1, rs2, off }
            | Instr::Bne { rs1, rs2, off }
            | Instr::Blt { rs1, rs2, off }
            | Instr::Bge { rs1, rs2, off } => {
                let poll_loop = matches!(i, Instr::Bne { .. })
                    && rd_val(&regs, rs1) == Val::Dmstat
                    && known(rd_val(&regs, rs2)) == Some(0)
                    && off < 0;
                if poll_loop {
                    // Canonical dma-wait: `poll: dmstat t1; bne t1,
                    // x0, poll`. Resolve as "looped until idle": the
                    // branch ultimately falls through with every
                    // transfer retired.
                    let t = pc as i64 + (off / 4) as i64;
                    let target_is_dmstat = usize::try_from(t)
                        .ok()
                        .and_then(|t| p.instrs.get(t))
                        .is_some_and(|ti| {
                            matches!(ti, Instr::Dmstat { .. })
                        });
                    if !target_is_dmstat {
                        return Err(
                            "branch on dmstat outside the poll idiom"
                                .into(),
                        );
                    }
                    f.polls += 1;
                    in_flight = 0;
                    set(&mut regs, rs1, Val::Known(0));
                } else {
                    let a = need(&regs, rs1, "branch")?;
                    let b = need(&regs, rs2, "branch")?;
                    let taken = match i {
                        Instr::Beq { .. } => a == b,
                        Instr::Bne { .. } => a != b,
                        Instr::Blt { .. } => (a as i32) < (b as i32),
                        _ => (a as i32) >= (b as i32),
                    };
                    if taken {
                        f.taken_branches += 1;
                        next = usize::try_from(
                            pc as i64 + (off / 4) as i64,
                        )
                        .map_err(|_| "branch before pc 0".to_string())?;
                    }
                }
            }
            Instr::Jal { rd, off } => {
                set(&mut regs, rd, Val::Unknown);
                f.taken_branches += 1;
                next = usize::try_from(pc as i64 + (off / 4) as i64)
                    .map_err(|_| "jump before pc 0".to_string())?;
            }
            Instr::Lw { rd, .. } => {
                f.has_lsu = true;
                set(&mut regs, rd, Val::Unknown);
            }
            Instr::Sw { .. } | Instr::Fld { .. } => {
                f.has_lsu = true;
            }
            Instr::Fsd { .. } => {
                f.has_lsu = true;
                f.drain_points += 1;
            }
            Instr::Csrrw { rd, csr: c, .. }
            | Instr::Csrrs { rd, csr: c, .. } => {
                if c == csr::SSR_ENABLE {
                    return Err(
                        "csrrw/csrrs on ssr_enable is not modeled"
                            .into(),
                    );
                }
                set(&mut regs, rd, Val::Unknown);
            }
            Instr::Csrrsi { csr: c, imm } => {
                if c == csr::SSR_ENABLE && imm & 1 == 1 {
                    // Enable region opens: every armed stream may
                    // request from here (read streams prefetch
                    // immediately, write streams on FP writeback).
                    for a in armed.iter().flatten() {
                        f.uses.push((segment, *a));
                        f.ssr_elements += a.elements();
                    }
                }
            }
            Instr::Csrrci { csr: c, imm } => {
                if c == csr::SSR_ENABLE && imm & 1 == 1 {
                    f.drain_points += 1;
                }
            }
            Instr::SsrCfgW { value, ssr, field } => {
                let v = need(&regs, value, "scfgw")?;
                let s = ssr as usize;
                if s >= geom.len() {
                    return Err(format!("scfgw to stream {s}"));
                }
                match field {
                    SsrField::Repeat => {}
                    SsrField::Bound(d) => {
                        geom[s].bounds[d as usize] = v;
                    }
                    SsrField::Stride(d) => {
                        geom[s].strides[d as usize] = v;
                    }
                    SsrField::ReadBase(d) | SsrField::WriteBase(d) => {
                        armed[s] = Some(Arming {
                            base: v,
                            dims: d as usize + 1,
                            geom: geom[s],
                        });
                    }
                }
            }
            Instr::FcvtDW { .. } => {}
            Instr::FmaddD { .. }
            | Instr::FmulD { .. }
            | Instr::FaddD { .. }
            | Instr::FsubD { .. }
            | Instr::FmaxD { .. }
            | Instr::FsgnjD { .. }
            | Instr::FgeluD { .. } => {}
            Instr::Frep { .. } => {
                // One frontend slot: the body offloads to the
                // sequencer ring buffer as it streams past; replays
                // are sequencer-side and cost no frontend slots.
            }
            Instr::Dmsrc { rs1 } => dma.src = need(&regs, rs1, "dmsrc")?,
            Instr::Dmdst { rs1 } => dma.dst = need(&regs, rs1, "dmdst")?,
            Instr::Dmstr { rs1, rs2 } => {
                dma.src_stride = need(&regs, rs1, "dmstr")?;
                dma.dst_stride = need(&regs, rs2, "dmstr")?;
            }
            Instr::Dmrep { rs1 } => {
                dma.reps = need(&regs, rs1, "dmrep")?.max(1);
            }
            Instr::Dmstr2 { rs1, rs2 } => {
                dma.src_stride2 = need(&regs, rs1, "dmstr2")?;
                dma.dst_stride2 = need(&regs, rs2, "dmstr2")?;
            }
            Instr::Dmrep2 { rs1 } => {
                dma.reps2 = need(&regs, rs1, "dmrep2")?.max(1);
            }
            Instr::Dmcpy { rd, rs1 } => {
                let size = need(&regs, rs1, "dmcpy")?;
                if size == 0 || size % 8 != 0 {
                    return Err(format!("dmcpy size {size}"));
                }
                f.dmas.push(DmaXfer {
                    src: dma.src,
                    dst: dma.dst,
                    size,
                    src_stride: dma.src_stride,
                    dst_stride: dma.dst_stride,
                    reps: dma.reps,
                    src_stride2: dma.src_stride2,
                    dst_stride2: dma.dst_stride2,
                    reps2: dma.reps2,
                    segment,
                });
                in_flight += 1;
                set(&mut regs, rd, Val::Unknown);
            }
            Instr::Dmstat { rd } => {
                set(&mut regs, rd, Val::Dmstat);
            }
            Instr::Barrier => {
                if in_flight > 0 {
                    f.wait_aligned = false;
                }
                f.barriers += 1;
                segment += 1;
            }
            Instr::Ecall => {
                if in_flight > 0 {
                    f.wait_aligned = false;
                }
                f.halted = true;
                break;
            }
            Instr::Nop => {}
        }
        pc = next;
    }
    Ok(f)
}

// ------------------------------------------------------------------
// RAW-hazard distance analysis
// ------------------------------------------------------------------

/// Minimum write→read reuse distance over the FP register file,
/// measured in FP issue slots (a sound under-approximation of cycles:
/// the machine issues at most one FP op per cycle, in program order).
///
/// Repetition is handled by *regions*: every FREP capture window and
/// every backward-branch loop body contributes wraparound pairs
/// `(write at slot i, read at slot j <= i)` with cyclic distance
/// `(end - i) + (j - start)`. Conservative in the proving direction:
/// SSR-intercepted operands are treated as real register traffic, so
/// the computed minimum can only be smaller than the machine's.
fn min_fp_reuse_distance(p: &Program) -> u64 {
    // FP issue slots: (dest, sources). `fcvt.d.w` writes its register
    // directly in the frontend (no pipeline dwell), so it is neither
    // a slot nor a busy-marking write.
    let mut slots: Vec<(Option<u8>, [Option<u8>; 3])> = Vec::new();
    let mut slot_at: Vec<usize> = Vec::with_capacity(p.instrs.len());
    let mut regions: Vec<(usize, usize)> = Vec::new();
    // Open FREP capture windows: (fp slots still to capture, start).
    let mut open: Vec<(usize, usize)> = Vec::new();
    for (pos, i) in p.instrs.iter().enumerate() {
        slot_at.push(slots.len());
        if i.is_fp_compute() {
            slots.push((i.fp_dest(), i.fp_sources()));
            for o in open.iter_mut() {
                o.0 -= 1;
            }
            open.retain(|&(rem, start)| {
                if rem == 0 {
                    regions.push((start, slots.len()));
                    false
                } else {
                    true
                }
            });
        } else {
            match *i {
                Instr::Frep { n_inst, .. } => {
                    open.push((n_inst as usize + 1, slots.len()));
                }
                Instr::Beq { off, .. }
                | Instr::Bne { off, .. }
                | Instr::Blt { off, .. }
                | Instr::Bge { off, .. }
                | Instr::Jal { off, .. } => {
                    if off < 0 {
                        let t = pos as i64 + (off / 4) as i64;
                        if let Ok(t) = usize::try_from(t) {
                            if t < slot_at.len() {
                                regions
                                    .push((slot_at[t], slots.len()));
                            }
                        }
                    }
                }
                _ => {}
            }
        }
    }
    // Unterminated capture windows close at the end of the program.
    for (_, start) in open {
        regions.push((start, slots.len()));
    }

    let mut min_d = u64::MAX;
    // Linear pairs.
    let mut last_w = [usize::MAX; 32];
    for (t, (dest, srcs)) in slots.iter().enumerate() {
        for s in srcs.iter().flatten() {
            let lw = last_w[*s as usize];
            if lw != usize::MAX {
                min_d = min_d.min((t - lw) as u64);
            }
        }
        if let Some(d) = dest {
            last_w[*d as usize] = t;
        }
    }
    // Wraparound pairs per region.
    for &(s, e) in &regions {
        for i in s..e {
            let Some(d) = slots[i].0 else { continue };
            for (j, slot) in slots.iter().enumerate().take(i + 1).skip(s)
            {
                if slot.1.iter().flatten().any(|&src| src == d) {
                    min_d = min_d.min((e - i + j - s) as u64);
                }
            }
        }
    }
    min_d
}

// ------------------------------------------------------------------
// Footprints and theorems
// ------------------------------------------------------------------

/// Word- and superbank-level footprint of one unique traffic source
/// (an SSR arming or one side of a DMA descriptor shape).
struct Foot {
    words: BTreeSet<u32>,
    sbanks: BTreeSet<usize>,
    /// Every address landed fully inside TCDM.
    in_range: bool,
}

fn foot_of(addrs: Vec<u32>, tcdm: &Tcdm) -> Foot {
    let mut f = Foot {
        words: BTreeSet::new(),
        sbanks: BTreeSet::new(),
        in_range: true,
    };
    for a in addrs {
        if tcdm.contains(a) && tcdm.contains(a.wrapping_add(7)) {
            f.words.insert(a & !7);
            f.sbanks.insert(tcdm.bank_of(a) / BANKS_PER_SUPERBANK);
        } else {
            f.in_range = false;
        }
    }
    f
}

/// Verify one cluster plan: the 8 compute programs + the DM program
/// against the configuration they were generated for. Pure; never
/// simulates.
pub fn verify_cluster_plan(
    cfg: &ClusterConfig,
    programs: &[Arc<Program>],
) -> StaticStallReport {
    if programs.len() != cfg.n_compute + 1 {
        return StaticStallReport::unknown(
            cfg.id,
            1,
            format!(
                "expected {} programs, got {}",
                cfg.n_compute + 1,
                programs.len()
            ),
        );
    }

    // The analyzer consumes the *encoded* stream: every word must
    // decode back to the IR it claims to be, or nothing else is
    // trustworthy.
    for (ci, p) in programs.iter().enumerate() {
        if p.words.len() != p.instrs.len() {
            return StaticStallReport::unknown(
                cfg.id,
                1,
                format!("core {ci}: words/instrs length mismatch"),
            );
        }
        for (pos, (&w, want)) in
            p.words.iter().zip(&p.instrs).enumerate()
        {
            if decode(w) != Some(*want) {
                return StaticStallReport::unknown(
                    cfg.id,
                    1,
                    format!(
                        "core {ci} pc {pos}: word {w:#010x} does not \
                         decode to {want:?}"
                    ),
                );
            }
        }
    }

    let mut facts = Vec::with_capacity(programs.len());
    for (ci, p) in programs.iter().enumerate() {
        match walk(p) {
            Ok(f) => facts.push(f),
            Err(e) => {
                return StaticStallReport::unknown(
                    cfg.id,
                    1,
                    format!("core {ci}: abstract walk bailed: {e}"),
                );
            }
        }
    }
    let dm = facts.len() - 1;
    let mut notes = Vec::new();
    let mut theorems = Vec::new();

    // ---- barriers_matched: lockstep segmentation + termination ----
    let n_barriers = facts[0].barriers;
    let barriers_ok = facts
        .iter()
        .all(|f| f.barriers == n_barriers && f.halted);
    theorems.push(Theorem {
        name: theorem::BARRIERS_MATCHED,
        holds: barriers_ok,
        detail: if barriers_ok {
            format!(
                "all {} cores run {n_barriers} barriers and halt",
                facts.len()
            )
        } else {
            "barrier counts diverge or a core never halts".into(),
        },
    });

    // ---- address footprints, deduplicated, tagged by segment ----
    // The double buffer alternates between two fixed buffer groups,
    // so across any number of passes only a handful of distinct
    // armings/descriptors exist: enumerate each footprint once and
    // reason per segment over footprint ids.
    let tcdm = Tcdm::new(cfg.topology, cfg.tcdm_bytes);
    let n_segs = facts.iter().map(|f| f.barriers).max().unwrap_or(0) + 1;
    let mut foots: Vec<Foot> = Vec::new();
    let mut ids: BTreeMap<(u8, Vec<u32>), usize> = BTreeMap::new();
    let mut seg_ssr = vec![BTreeSet::<usize>::new(); n_segs];
    let mut seg_dma = vec![BTreeSet::<usize>::new(); n_segs];
    for f in facts.iter().take(dm) {
        for (seg, a) in &f.uses {
            let (bounds, strides) = a.enum_params();
            let mut key = vec![a.base];
            key.extend(&bounds);
            key.extend(strides.iter().map(|&s| s as u32));
            let id = *ids.entry((0, key)).or_insert_with(|| {
                foots.push(foot_of(
                    oracle_addresses(a.base, &bounds, &strides),
                    &tcdm,
                ));
                foots.len() - 1
            });
            seg_ssr[(*seg).min(n_segs - 1)].insert(id);
        }
    }
    for x in &facts[dm].dmas {
        for (base, s1, s2) in [
            (x.src, x.src_stride, x.src_stride2),
            (x.dst, x.dst_stride, x.dst_stride2),
        ] {
            if !tcdm.contains(base) {
                continue;
            }
            let key = vec![base, x.size, s1, x.reps, s2, x.reps2];
            let id = *ids.entry((1, key)).or_insert_with(|| {
                foots.push(foot_of(
                    dma_side_addrs(base, x.size, s1, x.reps, s2, x.reps2),
                    &tcdm,
                ));
                foots.len() - 1
            });
            seg_dma[x.segment.min(n_segs - 1)].insert(id);
        }
    }
    let capacity_ok = foots.iter().all(|f| f.in_range);
    theorems.push(Theorem {
        name: theorem::CAPACITY_OK,
        holds: capacity_ok,
        detail: if capacity_ok {
            format!(
                "every SSR element and DMA beat inside the {} KiB TCDM",
                cfg.tcdm_bytes / 1024
            )
        } else {
            "an SSR element or DMA beat falls outside TCDM".into(),
        },
    });

    // ---- DMA phase disjointness + double-buffer race freedom ----
    // The temporal half of both proofs: (1) barriers release
    // globally, so only same-numbered segments overlap in time, and
    // (2) the DM program drains its transfers before every barrier,
    // so DMA beats of segment s fly only during segment s.
    let aligned = facts.iter().all(|f| f.wait_aligned) && barriers_ok;
    let lsu_free = !facts.iter().any(|f| f.has_lsu);
    let mut sbank_clash: Option<usize> = None;
    let mut word_clash: Option<usize> = None;
    for s in 0..n_segs {
        for &d in &seg_dma[s] {
            for &u in &seg_ssr[s] {
                if !foots[d].sbanks.is_disjoint(&foots[u].sbanks) {
                    sbank_clash.get_or_insert(s);
                }
                if !foots[d].words.is_disjoint(&foots[u].words) {
                    word_clash.get_or_insert(s);
                }
            }
        }
    }
    let dma_disjoint =
        aligned && lsu_free && capacity_ok && sbank_clash.is_none();
    theorems.push(Theorem {
        name: theorem::DMA_PHASE_DISJOINT,
        holds: dma_disjoint,
        detail: if dma_disjoint {
            "per segment, DMA superbanks and compute-stream \
             superbanks never meet"
                .into()
        } else if let Some(s) = sbank_clash {
            format!("segment {s}: DMA and SSR share a superbank")
        } else {
            "alignment/LSU/capacity precondition failed".into()
        },
    });
    let race_free =
        aligned && capacity_ok && word_clash.is_none();
    theorems.push(Theorem {
        name: theorem::DOUBLE_BUFFER_RACE_FREE,
        holds: race_free,
        detail: if race_free {
            "per segment, DMA words and SSR words are disjoint".into()
        } else if let Some(s) = word_clash {
            format!("segment {s}: DMA and SSR touch the same word")
        } else {
            "alignment/capacity precondition failed".into()
        },
    });

    // ---- FastPath region safety (same analyzer, same story) ----
    let region_safe = dm_program_region_safe(&programs[dm]);
    theorems.push(Theorem {
        name: theorem::REGION_SAFETY,
        holds: region_safe,
        detail: if region_safe {
            "DM program never touches the FP/SSR subsystem".into()
        } else {
            "DM program touches the FP/SSR subsystem".into()
        },
    });

    // ---- zero-overhead loop nests (structural claim) ----
    let compute_branchless =
        facts.iter().take(dm).all(|f| f.taken_branches == 0);
    let zonl = cfg.zonl && compute_branchless;
    theorems.push(Theorem {
        name: theorem::ZONL_ZERO_LOOP_OVERHEAD,
        holds: zonl,
        detail: if zonl {
            "compute loop nests run branch-free from the FREP \
             sequencer"
                .into()
        } else {
            "compute cores take software-loop branches".into()
        },
    });

    // ---- per-class verdicts ----
    let mut v = [Verdict::Unknown; N_CLASSES];
    let n_ports = cfg.n_ports() as u64;
    // Round-robin fairness: a continuously presented request loses a
    // contested bank cycle at most (ports - 1) times before its
    // grant, and the superbank mux alternates DMA/core priority —
    // 2*ports + 2 denied cycles per element request, worst case.
    let per_request = 2 * n_ports + 2;

    // ControlOverhead: every CO-classified cycle is a frontend slot
    // (int issue, branch bubble, or a post-completion poll tail) —
    // FP-issue cycles classify as Useful/SsrOperandWait/BankConflict
    // and never reach CO.
    let b_ctrl: u64 = facts
        .iter()
        .map(|f| {
            f.executions
                + cfg.core.taken_branch_penalty as u64
                    * f.taken_branches
                + CTRL_PER_POLL * f.polls
        })
        .sum::<u64>()
        + CTRL_SLACK;
    if lsu_free {
        v[StallClass::ControlOverhead as usize] = Verdict::Bounded(b_ctrl);
    } else {
        notes.push(
            "control_overhead: integer LSU traffic present (main-\
             memory dwell is unbounded here)"
                .into(),
        );
    }

    // RawHazard: impossible when every write→read reuse distance
    // covers the FPU pipeline and the pipe can never fill.
    let lat = cfg.core.fpu.latency as u64;
    let min_dist = programs
        .iter()
        .map(|p| min_fp_reuse_distance(p))
        .min()
        .unwrap_or(u64::MAX);
    // (`lsu_free` because an `fld` writeback is not in the distance
    // pass; generated kernels never load through the LSU.)
    if lsu_free && min_dist >= lat && cfg.core.fpu.depth as u64 >= lat {
        v[StallClass::RawHazard as usize] = Verdict::Impossible;
    } else {
        notes.push(format!(
            "raw_hazard: min FP reuse distance {min_dist} vs latency \
             {lat}"
        ));
    }

    // BankConflict: every conflict-stalled cycle is a denied request
    // cycle of some element, and fairness bounds denials per element.
    let ssr_elements: u64 =
        facts.iter().map(|f| f.ssr_elements).sum();
    if lsu_free {
        v[StallClass::BankConflict as usize] =
            Verdict::Bounded(ssr_elements.saturating_mul(per_request));
    } else {
        notes.push(
            "bank_conflict: integer LSU traffic present".into(),
        );
    }

    // Drain: each drain point empties the FPU pipe and flushes the
    // SSR write FIFOs — at most `depth` results still in the pipe
    // plus a full write FIFO per stream, each beat granted within
    // the fairness bound.
    let depth = cfg.core.fpu.depth as u64;
    let per_drain = lat + depth + (depth + 8) * per_request;
    let b_drain: u64 = facts
        .iter()
        .map(|f| f.drain_points)
        .sum::<u64>()
        .saturating_mul(per_drain);
    v[StallClass::Drain as usize] = Verdict::Bounded(b_drain);

    // NocGated: the single-cluster crossbar always grants; withdrawn
    // by `for_clusters(n > 1)`.
    v[StallClass::NocGated as usize] = Verdict::Impossible;

    // Useful / SsrOperandWait / DmaWait / Barrier are schedule-
    // dependent: no static claim.
    notes.push(
        "useful/ssr_operand_wait/dma_wait/barrier: schedule-dependent, \
         no static claim"
            .into(),
    );

    StaticStallReport {
        config: cfg.id,
        clusters: 1,
        verdicts: v,
        theorems,
        notes,
    }
}

/// Verify a prepared GEMM. Model-only backends carry no programs —
/// they are regenerated here (planning is deterministic, so these are
/// the exact streams a cycle backend would run).
pub fn verify_prepared(
    prep: &crate::backend::PreparedGemm,
) -> StaticStallReport {
    let cfg = prep.config.cluster_config();
    if prep.programs.is_empty() {
        let programs: Vec<Arc<Program>> =
            crate::kernels::build_programs_fused(
                &cfg,
                &prep.plan.tiling,
                &prep.plan.map,
                prep.plan.epi,
            )
            .into_iter()
            .map(Arc::new)
            .collect();
        verify_cluster_plan(&cfg, &programs)
    } else {
        verify_cluster_plan(&cfg, &prep.programs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::Asm;
    use crate::isa::reg;
    use crate::kernels::{
        build_programs_fused, plan_gemm_fused, Activation, Epilogue,
        LayoutKind,
    };

    fn report_for(
        id: ConfigId,
        m: usize,
        n: usize,
        k: usize,
        epi: Epilogue,
    ) -> StaticStallReport {
        let cfg = id.cluster_config();
        let plan =
            plan_gemm_fused(&cfg, m, n, k, LayoutKind::Grouped, epi)
                .unwrap();
        let programs: Vec<Arc<Program>> =
            build_programs_fused(&cfg, &plan.tiling, &plan.map, epi)
                .into_iter()
                .map(Arc::new)
                .collect();
        verify_cluster_plan(&cfg, &programs)
    }

    fn holds(r: &StaticStallReport, name: &str) -> bool {
        r.theorem(name).map(|t| t.holds).unwrap_or(false)
    }

    #[test]
    fn dobu_plans_prove_the_paper_claims() {
        for &(m, n, k) in &[(32, 32, 32), (64, 64, 64), (32, 64, 40)] {
            for epi in [
                Epilogue::NONE,
                Epilogue { bias: true, act: Some(Activation::Relu) },
            ] {
                let r =
                    report_for(ConfigId::Zonl48Db, m, n, k, epi);
                for t in [
                    theorem::BARRIERS_MATCHED,
                    theorem::CAPACITY_OK,
                    theorem::DMA_PHASE_DISJOINT,
                    theorem::DOUBLE_BUFFER_RACE_FREE,
                    theorem::REGION_SAFETY,
                    theorem::ZONL_ZERO_LOOP_OVERHEAD,
                ] {
                    assert!(
                        holds(&r, t),
                        "{m}x{n}x{k} {epi:?}: {t} should hold: {:?}",
                        r.theorem(t)
                    );
                }
                assert_eq!(
                    r.verdict(StallClass::RawHazard),
                    Verdict::Impossible
                );
                assert_eq!(
                    r.verdict(StallClass::NocGated),
                    Verdict::Impossible
                );
                assert!(matches!(
                    r.verdict(StallClass::ControlOverhead),
                    Verdict::Bounded(_)
                ));
                assert!(matches!(
                    r.verdict(StallClass::BankConflict),
                    Verdict::Bounded(_)
                ));
                assert!(matches!(
                    r.verdict(StallClass::Drain),
                    Verdict::Bounded(_)
                ));
                assert_eq!(
                    r.verdict(StallClass::DmaWait),
                    Verdict::Unknown
                );
            }
        }
    }

    #[test]
    fn baseline_config_takes_software_loop_branches() {
        let r =
            report_for(ConfigId::Base32Fc, 32, 32, 32, Epilogue::NONE);
        assert!(!holds(&r, theorem::ZONL_ZERO_LOOP_OVERHEAD));
        // Everything else still proves: the double buffer and the
        // barrier discipline are layout properties, not ZONL ones.
        assert!(holds(&r, theorem::BARRIERS_MATCHED));
        assert!(holds(&r, theorem::DOUBLE_BUFFER_RACE_FREE));
        assert!(holds(&r, theorem::REGION_SAFETY));
        assert!(matches!(
            r.verdict(StallClass::ControlOverhead),
            Verdict::Bounded(_)
        ));
    }

    #[test]
    fn fc32_shared_superbanks_defeat_phase_disjointness() {
        // 64^3 forces a multi-pass plan, so DMA loads and SSR streams
        // share segments. 32 flat-interleaved banks = 4 superbanks
        // that every buffer spans, so the Dobu theorem must NOT be
        // claimed (claiming it would gate `tcdm_conflicts_dma == 0`,
        // which those configs do not deliver).
        let r =
            report_for(ConfigId::Base32Fc, 64, 64, 64, Epilogue::NONE);
        assert!(!holds(&r, theorem::DMA_PHASE_DISJOINT));
        // Word-level race freedom is weaker and still proves.
        assert!(holds(&r, theorem::DOUBLE_BUFFER_RACE_FREE));
    }

    #[test]
    fn region_safety_matches_the_legacy_scan() {
        let cfg = ConfigId::Zonl48Db.cluster_config();
        let plan = plan_gemm_fused(
            &cfg,
            32,
            32,
            32,
            LayoutKind::Grouped,
            Epilogue::NONE,
        )
        .unwrap();
        let progs = build_programs_fused(
            &cfg,
            &plan.tiling,
            &plan.map,
            Epilogue::NONE,
        );
        let dm = progs.last().unwrap();
        assert!(dm_program_region_safe(dm));
        // Compute programs touch SSRs: never region-safe.
        assert!(!dm_program_region_safe(&progs[0]));
        // An FP load disqualifies.
        let mut a = Asm::new();
        a.push(Instr::Fld { frd: 0, rs1: reg::A0, imm: 0 });
        a.push(Instr::Ecall);
        assert!(!dm_program_region_safe(&a.assemble()));
    }

    #[test]
    fn for_clusters_scales_bounds_and_drops_nocgated() {
        let r =
            report_for(ConfigId::Zonl48Db, 32, 32, 32, Epilogue::NONE);
        let Verdict::Bounded(b1) =
            r.verdict(StallClass::ControlOverhead)
        else {
            panic!("expected bounded CO");
        };
        let r4 = r.for_clusters(4);
        assert_eq!(r4.clusters, 4);
        assert_eq!(
            r4.verdict(StallClass::ControlOverhead),
            Verdict::Bounded(4 * b1)
        );
        assert_eq!(
            r4.verdict(StallClass::NocGated),
            Verdict::Unknown
        );
        // Impossible claims that don't rest on the lone crossbar
        // survive sharding.
        assert_eq!(
            r4.verdict(StallClass::RawHazard),
            Verdict::Impossible
        );
        // n = 1 is the identity.
        let r1 = r.for_clusters(1);
        assert_eq!(
            r1.verdict(StallClass::NocGated),
            Verdict::Impossible
        );
    }

    #[test]
    fn gate_flags_impossible_and_bound_violations() {
        let r =
            report_for(ConfigId::Zonl48Db, 32, 32, 32, Epilogue::NONE);
        let clean = [0u64; N_CLASSES];
        assert!(r.gate("test", &clean).is_empty());
        let mut bad = clean;
        bad[StallClass::RawHazard as usize] = 1;
        let fails = r.gate("test", &bad);
        assert_eq!(fails.len(), 1);
        assert!(fails[0].contains("raw_hazard"), "{fails:?}");
        let Verdict::Bounded(b) = r.verdict(StallClass::Drain) else {
            panic!()
        };
        let mut over = clean;
        over[StallClass::Drain as usize] = b + 1;
        assert_eq!(r.gate("test", &over).len(), 1);
        let mut under = clean;
        under[StallClass::Drain as usize] = b;
        assert!(r.gate("test", &under).is_empty());
        // DMA facet.
        assert!(r.gate_dma("test", 0).is_none());
        assert!(r.gate_dma("test", 3).is_some());
    }

    #[test]
    fn corrupted_encoding_degrades_to_unknown() {
        let cfg = ConfigId::Zonl48Db.cluster_config();
        let plan = plan_gemm_fused(
            &cfg,
            32,
            32,
            32,
            LayoutKind::Grouped,
            Epilogue::NONE,
        )
        .unwrap();
        let mut progs = build_programs_fused(
            &cfg,
            &plan.tiling,
            &plan.map,
            Epilogue::NONE,
        );
        progs[0].words[0] ^= 0xFFFF_FFFF;
        let programs: Vec<Arc<Program>> =
            progs.into_iter().map(Arc::new).collect();
        let r = verify_cluster_plan(&cfg, &programs);
        assert!(r
            .verdicts
            .iter()
            .all(|v| *v == Verdict::Unknown));
        assert!(r.notes[0].contains("decode"), "{:?}", r.notes);
    }

    #[test]
    fn unmodeled_programs_degrade_to_unknown_not_unsound() {
        // A data-dependent branch is outside the concrete fragment.
        let cfg = ConfigId::Zonl48Db.cluster_config();
        let mut progs = Vec::new();
        for _ in 0..cfg.n_compute + 1 {
            let mut a = Asm::new();
            a.push(Instr::Csrrs {
                rd: reg::T0,
                csr: csr::MCYCLE,
                rs1: reg::ZERO,
            });
            let skip = a.label();
            a.bne(reg::T0, reg::ZERO, skip);
            a.bind(skip);
            a.push(Instr::Ecall);
            progs.push(Arc::new(a.assemble()));
        }
        let r = verify_cluster_plan(&cfg, &progs);
        assert!(r
            .verdicts
            .iter()
            .all(|v| *v == Verdict::Unknown));
    }

    #[test]
    fn raw_hazard_distance_sees_frep_wraparound() {
        // frep over a 2-op body where op1 writes f10 and op0 reads it
        // next iteration: cyclic distance 2 < latency 3.
        let mut a = Asm::new();
        a.li(reg::T1, 7);
        a.push(Instr::Frep {
            outer: true,
            iters_reg: reg::T1,
            n_inst: 1,
        });
        a.push(Instr::FaddD { frd: 11, frs1: 10, frs2: 10 });
        a.push(Instr::FmulD { frd: 10, frs1: 11, frs2: 11 });
        a.push(Instr::Ecall);
        let p = a.assemble();
        assert_eq!(min_fp_reuse_distance(&p), 1);
    }
}
