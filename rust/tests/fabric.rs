//! ClusterFabric integration tests: sharded cycle-backend GEMMs stay
//! bit-identical to the single-cluster driver across every zoo shape,
//! the NoC arbiter's contention is visible (and harmless to
//! numerics), and the 4-cluster analytic fabric delivers the expected
//! near-linear speedup on compute-bound shapes.

use std::collections::HashSet;

use zerostall::cluster::ConfigId;
use zerostall::coordinator::workload::graph::NetOp;
use zerostall::coordinator::workload::{zoo, Problem};
use zerostall::coordinator::experiments;
use zerostall::fabric::{FabricConfig, NocConfig};
use zerostall::kernels::{
    run_matmul_fused, test_bias, test_matrices, Epilogue, GemmJob,
    GemmService, LayoutKind,
};

/// Every distinct (shape, epilogue) GEMM the model zoo contains.
fn zoo_gemms() -> Vec<(usize, usize, usize, Epilogue)> {
    let mut seen = HashSet::new();
    let mut out = Vec::new();
    for name in zoo::models() {
        let g = zoo::build(name).unwrap();
        for op in &g.ops {
            if let NetOp::Gemm { x, w, epi, .. } = op {
                let (xt, wt) = (&g.tensors[*x], &g.tensors[*w]);
                let key = (xt.rows, wt.cols, xt.cols, epi.name());
                if seen.insert(key) {
                    out.push((xt.rows, wt.cols, xt.cols, *epi));
                }
            }
        }
    }
    out
}

#[test]
fn sharded_cycle_bit_identical_across_zoo_shapes() {
    // Acceptance: sharded cycle-backend GEMM (N clusters) produces
    // bit-identical C to the single-cluster driver for every zoo
    // shape — K stays shard-local, so no FMA reorders anywhere.
    let svc = GemmService::cycle();
    let fabric = FabricConfig::new(4);
    let config = ConfigId::Zonl48Db;
    let shapes = zoo_gemms();
    assert!(shapes.len() >= 8, "zoo should cover many shapes");
    for (m, n, k, epi) in shapes {
        let seed = zerostall::kernels::problem_seed(m, n, k);
        let (a, b) = test_matrices(m, n, k, seed);
        let bias = if epi.bias {
            test_bias(n, seed)
        } else {
            Vec::new()
        };
        let lone =
            run_matmul_fused(config, m, n, k, epi, &a, &b, &bias)
                .unwrap();
        let fab = svc
            .run_sharded(
                config,
                m,
                n,
                k,
                LayoutKind::Grouped,
                epi,
                &a,
                &b,
                &bias,
                &fabric,
            )
            .unwrap();
        assert!(
            fab.clusters() > 1,
            "{m}x{n}x{k}: zoo shapes must shard"
        );
        assert_eq!(
            fab.c, lone.c,
            "{m}x{n}x{k} ({}): sharded C differs from the \
             single-cluster driver",
            epi.name()
        );
    }
}

#[test]
fn noc_contention_slows_but_never_corrupts() {
    // Same sharded GEMM on a starved (1-beat) vs generous (4-beat)
    // NoC: identical numerics, strictly more cycles when starved.
    let config = ConfigId::Zonl48Db;
    let (m, n, k) = (64, 64, 16);
    let (a, b) = test_matrices(m, n, k, 77);
    let svc = GemmService::cycle();
    let run = |noc: NocConfig| {
        let fabric = FabricConfig { clusters: 4, noc };
        svc.run_sharded(
            config,
            m,
            n,
            k,
            LayoutKind::Grouped,
            Epilogue::NONE,
            &a,
            &b,
            &[],
            &fabric,
        )
        .unwrap()
    };
    let starved = run(NocConfig { links: 1, beats_per_link: 1 });
    let generous = run(NocConfig { links: 4, beats_per_link: 1 });
    assert_eq!(starved.c, generous.c, "arbitration must not touch data");
    assert!(
        starved.cycles > generous.cycles,
        "1-beat NoC must be slower: {} vs {}",
        starved.cycles,
        generous.cycles
    );
    assert!(starved.noc.denials > generous.noc.denials);
    // A private-bandwidth NoC never saturates with 4 branches.
    assert_eq!(generous.noc.saturated_cycles, 0);
}

#[test]
fn four_cluster_analytic_sweep_speedup_and_utilization() {
    // Acceptance: a 4-cluster analytic sweep shows end-to-end speedup
    // > 3x on compute-bound shapes with per-cluster utilization
    // within 2 points of the single-cluster run.
    let svc = GemmService::analytic();
    let fabric = FabricConfig::new(4);
    let config = ConfigId::Zonl48Db;
    for (m, n, k) in [(128, 128, 128), (96, 96, 96), (64, 64, 128)] {
        let p = Problem { m, n, k };
        let lone = experiments::run_point_with(
            &svc,
            config,
            p,
            LayoutKind::Grouped,
        )
        .unwrap();
        let fab = svc
            .run_sharded_job(
                &GemmJob::for_problem(
                    config,
                    m,
                    n,
                    k,
                    LayoutKind::Grouped,
                ),
                &fabric,
            )
            .unwrap();
        assert_eq!(fab.clusters(), 4, "{m}x{n}x{k} must use the fabric");
        let speedup = lone.cycles as f64 / fab.cycles as f64;
        assert!(
            speedup > 3.0,
            "{m}x{n}x{k}: speedup {speedup:.2} <= 3 (lone {} fabric {})",
            lone.cycles,
            fab.cycles
        );
        let du = (fab.mean_utilization() - lone.utilization).abs();
        assert!(
            du < 0.02,
            "{m}x{n}x{k}: per-cluster utilization drifted {du:.3} \
             (shard {:.3} vs single {:.3})",
            fab.mean_utilization(),
            lone.utilization
        );
        // The fabric-level row reports scaled throughput.
        let row = experiments::run_point_sharded(
            &svc,
            config,
            p,
            LayoutKind::Grouped,
            &fabric,
        )
        .unwrap();
        assert!(
            row.gflops > 3.0 * lone.gflops,
            "{m}x{n}x{k}: fabric throughput {:.1} vs single {:.1}",
            row.gflops,
            lone.gflops
        );
    }
}

#[test]
fn sharded_analytic_matches_cycle_fabric_shape() {
    // The analytic NoC-contention term tracks the cycle fabric on a
    // mid-size sharded GEMM: same shard count, end-to-end cycles
    // within the calibrated model's usual error band.
    let config = ConfigId::Zonl48Db;
    let (m, n, k) = (64, 64, 64);
    let fabric = FabricConfig::new(4);
    let job =
        GemmJob::for_problem(config, m, n, k, LayoutKind::Grouped);
    let cyc = GemmService::cycle()
        .run_sharded_job(&job, &fabric)
        .unwrap();
    let ana = GemmService::analytic()
        .run_sharded_job(&job, &fabric)
        .unwrap();
    assert_eq!(cyc.clusters(), ana.clusters());
    let err = (ana.cycles as f64 - cyc.cycles as f64).abs()
        / cyc.cycles as f64;
    assert!(
        err < 0.35,
        "analytic fabric cycles off by {err:.2} ({} vs {})",
        ana.cycles,
        cyc.cycles
    );
}
