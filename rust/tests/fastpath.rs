//! FastPath equivalence suite: fast-forwarded, thread-stepped, and
//! memoized runs must be bit-identical to naive per-cycle stepping —
//! C matrix, total cycles, the full StallProfile breakdown, and every
//! perf counter — across random fused and sharded jobs, thread
//! counts, and repeated serve traces.
//!
//! These are the hard acceptance gates for the FastPath rework: any
//! observable drift between the tiers is a bug in the fast path, not
//! an accuracy tradeoff.

use zerostall::backend::CycleAccurate;
use zerostall::cluster::{ClusterPerf, ConfigId};
use zerostall::coordinator::serve::{
    serve, Policy, ServeConfig, ServeEngine,
};
use zerostall::fabric::FabricConfig;
use zerostall::kernels::{
    problem_seed, test_bias, test_matrices, Activation, Epilogue,
    GemmJob, GemmService, LayoutKind,
};
use zerostall::util::prop::{check, Config};

fn cfg(seed: u64) -> Config {
    // Cycle-accurate property: a fraction of the default budget.
    let base = Config::default();
    Config { cases: (base.cases / 8).max(6), seed }
}

fn epi_of(code: usize) -> Epilogue {
    match code % 6 {
        0 => Epilogue::NONE,
        1 => Epilogue { bias: true, act: None },
        2 => Epilogue { bias: false, act: Some(Activation::Relu) },
        3 => Epilogue { bias: true, act: Some(Activation::Relu) },
        4 => Epilogue { bias: false, act: Some(Activation::Gelu) },
        _ => Epilogue { bias: true, act: Some(Activation::Gelu) },
    }
}

/// Compare every observable of two cluster-perf snapshots; `Err`
/// names the first field that drifts.
fn perf_eq(tag: &str, a: &ClusterPerf, b: &ClusterPerf) -> Result<(), String> {
    macro_rules! cmp {
        ($($f:ident),+ $(,)?) => {
            $(
                if a.$f != b.$f {
                    return Err(format!(
                        "{tag}: perf.{} differs: {:?} vs {:?}",
                        stringify!($f), a.$f, b.$f
                    ));
                }
            )+
        };
    }
    cmp!(
        cycles,
        window_cycles,
        fpu_ops_per_core,
        fpu_ops_total,
        stall_ssr_empty,
        stall_wfifo,
        stall_raw,
        stall_fpu_full,
        fpu_idle_no_instr,
        offload_stalls,
        branch_bubbles,
        barrier_cycles,
        lsu_stalls,
        int_instrs,
        icache_fetches,
        rb_replays,
        csr_instrs,
        tcdm_core_accesses,
        tcdm_conflicts,
        tcdm_conflicts_dma,
        ssr_requests,
        ssr_conflicts,
        dma_beats,
        dma_bytes,
        dma_busy_cycles,
        dma_stall_cycles,
        dma_noc_gated_cycles,
        tcdm_conflict_cycles,
        barriers_completed,
        stalls,
    );
    if a.utilization.to_bits() != b.utilization.to_bits() {
        return Err(format!(
            "{tag}: utilization differs: {} vs {}",
            a.utilization, b.utilization
        ));
    }
    Ok(())
}

fn svc_threads(fast_forward: bool, threads: usize) -> GemmService {
    GemmService::new(Box::new(CycleAccurate { fast_forward, threads }))
}

#[test]
fn prop_fastforward_fused_bit_identical() {
    let naive = GemmService::cycle_naive();
    let fast = GemmService::cycle();
    check(
        &cfg(0xFA57_0001),
        |rng| {
            vec![
                rng.range(1, 5) * 8, // m
                rng.range(1, 5) * 8, // n
                rng.range(1, 5) * 8, // k
                rng.range(0, 5),     // config index
                rng.range(0, 6),     // epilogue code
            ]
        },
        |v| {
            if v.len() < 5 {
                return Ok(());
            }
            // Round shrunk values back onto the planner's 8-grid.
            let m = (v[0].max(8) / 8) * 8;
            let n = (v[1].max(8) / 8) * 8;
            let k = (v[2].max(8) / 8) * 8;
            let id = ConfigId::all()[v[3] % 5];
            let epi = epi_of(v[4]);
            let tag = format!("{m}x{n}x{k} {} {:?}", id.name(), epi);
            let seed = problem_seed(m, n, k);
            let (a, b) = test_matrices(m, n, k, seed);
            let bias =
                if epi.bias { test_bias(n, seed) } else { Vec::new() };
            let slow = naive
                .run_fused(
                    id,
                    m,
                    n,
                    k,
                    LayoutKind::Grouped,
                    epi,
                    &a,
                    &b,
                    &bias,
                )
                .map_err(|e| format!("{tag}: naive: {e}"))?;
            let quick = fast
                .run_fused(
                    id,
                    m,
                    n,
                    k,
                    LayoutKind::Grouped,
                    epi,
                    &a,
                    &b,
                    &bias,
                )
                .map_err(|e| format!("{tag}: fastpath: {e}"))?;
            if quick.c != slow.c {
                return Err(format!("{tag}: C differs"));
            }
            if quick.cycles != slow.cycles {
                return Err(format!(
                    "{tag}: cycles differ: {} vs {}",
                    quick.cycles, slow.cycles
                ));
            }
            perf_eq(&tag, &quick.perf, &slow.perf)
        },
    );
}

#[test]
fn prop_fastforward_sharded_bit_identical_across_threads() {
    let naive = GemmService::cycle_naive();
    // Two fast services with different fabric thread counts: results
    // must not depend on host parallelism.
    let fast1 = svc_threads(true, 1);
    let fast3 = svc_threads(true, 3);
    let fabric = FabricConfig::new(4);
    check(
        &cfg(0xFA57_0002),
        |rng| {
            vec![
                rng.range(1, 4) * 16, // m (shardable)
                rng.range(1, 4) * 16, // n
                rng.range(1, 4) * 8,  // k
                rng.range(0, 5),      // config index
                rng.range(0, 6),      // epilogue code
            ]
        },
        |v| {
            if v.len() < 5 {
                return Ok(());
            }
            let m = (v[0].max(16) / 8) * 8;
            let n = (v[1].max(16) / 8) * 8;
            let k = (v[2].max(8) / 8) * 8;
            let id = ConfigId::all()[v[3] % 5];
            let epi = epi_of(v[4]);
            let tag =
                format!("sharded {m}x{n}x{k} {} {:?}", id.name(), epi);
            let seed = problem_seed(m, n, k);
            let (a, b) = test_matrices(m, n, k, seed);
            let bias =
                if epi.bias { test_bias(n, seed) } else { Vec::new() };
            let run = |svc: &GemmService| {
                svc.run_sharded(
                    id,
                    m,
                    n,
                    k,
                    LayoutKind::Grouped,
                    epi,
                    &a,
                    &b,
                    &bias,
                    &fabric,
                )
            };
            let slow = run(&naive)
                .map_err(|e| format!("{tag}: naive: {e}"))?;
            for (name, svc) in
                [("threads=1", &fast1), ("threads=3", &fast3)]
            {
                let quick = run(svc)
                    .map_err(|e| format!("{tag}: {name}: {e}"))?;
                if quick.c != slow.c {
                    return Err(format!("{tag}: {name}: C differs"));
                }
                if quick.cycles != slow.cycles {
                    return Err(format!(
                        "{tag}: {name}: fabric cycles differ: {} vs {}",
                        quick.cycles, slow.cycles
                    ));
                }
                if quick.noc.grants != slow.noc.grants
                    || quick.noc.denials != slow.noc.denials
                    || quick.noc.saturated_cycles
                        != slow.noc.saturated_cycles
                {
                    return Err(format!(
                        "{tag}: {name}: NoC stats differ: {:?} vs {:?}",
                        quick.noc, slow.noc
                    ));
                }
                if quick.shards.len() != slow.shards.len() {
                    return Err(format!(
                        "{tag}: {name}: shard count differs"
                    ));
                }
                for (i, (q, s)) in
                    quick.shards.iter().zip(&slow.shards).enumerate()
                {
                    if q.cycles != s.cycles {
                        return Err(format!(
                            "{tag}: {name}: shard {i} cycles differ"
                        ));
                    }
                    perf_eq(
                        &format!("{tag}: {name}: shard {i}"),
                        &q.perf,
                        &s.perf,
                    )?;
                }
            }
            Ok(())
        },
    );
}

#[test]
fn memo_tier_pins_hit_counts_on_repeated_jobs() {
    // Deterministic golden: one shape submitted five times costs one
    // simulation and four replays — and the replays are bit-identical
    // to the simulated first run.
    let svc = GemmService::replay();
    let job = GemmJob::fused(
        ConfigId::Zonl48Db,
        16,
        16,
        16,
        LayoutKind::Grouped,
        Epilogue { bias: true, act: Some(Activation::Relu) },
    );
    let first = svc.run_job(&job).unwrap();
    for _ in 0..4 {
        let again = svc.run_job(&job).unwrap();
        assert_eq!(again.c, first.c);
        assert_eq!(again.cycles, first.cycles);
        perf_eq("memo repeat", &again.perf, &first.perf).unwrap();
    }
    let stats = svc.memo_stats().unwrap();
    assert_eq!(
        (stats.hits, stats.misses),
        (4, 1),
        "memo golden: exactly one simulation, four replays"
    );
    // A different shape is a new key.
    let other = GemmJob::for_problem(
        ConfigId::Zonl48Db,
        24,
        16,
        16,
        LayoutKind::Grouped,
    );
    svc.run_job(&other).unwrap();
    let stats = svc.memo_stats().unwrap();
    assert_eq!((stats.hits, stats.misses), (4, 2));
}

#[test]
fn memo_tier_matches_cycle_on_repeated_shape_serve_trace() {
    // A short bursty trace over a two-model mix on a 2-cluster
    // fabric: the replay tier must reproduce the cycle backend's
    // serve report bit for bit (identical makespan, latency rows,
    // stall totals, plan stats), while serving most submissions from
    // the memo.
    let mut cfg = ServeConfig::new(vec!["ffn".to_string()]);
    cfg.clusters = 2;
    cfg.requests = 6;
    cfg.rate_per_mcycle = 20.0;
    cfg.burst = 0.25;
    cfg.policy = Policy::Continuous;
    cfg.seed = 7;
    cfg.threads = 2;
    // This test pins the *backend* memo tier's hit/miss goldens, so
    // it runs the wave-synchronous engine: the event core's own
    // dispatch memo would (correctly) starve the replay tier of the
    // repeat submissions the assertions below count.
    cfg.engine = ServeEngine::Legacy;

    let cyc_svc = GemmService::cycle();
    let rep_svc = GemmService::replay();
    let cyc = serve(&cyc_svc, &cfg).unwrap();
    let rep = serve(&rep_svc, &cfg).unwrap();

    assert_eq!(rep.rows, cyc.rows, "per-request rows must replay");
    let mut rep_report = rep.report.clone();
    rep_report.backend = cyc.report.backend;
    assert_eq!(
        rep_report, cyc.report,
        "serve report identical modulo the backend label"
    );

    // Memo accounting golden: a repeated-shape trace replays most
    // submissions; a second identical trace replays *all* of them.
    let s1 = rep_svc.memo_stats().unwrap();
    let total = s1.hits + s1.misses;
    assert!(s1.misses > 0, "first trace must simulate each new shape");
    assert!(
        s1.hits > s1.misses,
        "repeated-shape trace should mostly hit: {s1:?}"
    );
    let rep2 = serve(&rep_svc, &cfg).unwrap();
    assert_eq!(rep2.rows, rep.rows, "same service, same trace");
    let s2 = rep_svc.memo_stats().unwrap();
    assert_eq!(
        s2.misses, s1.misses,
        "second trace must not simulate anything new"
    );
    assert_eq!(
        s2.hits,
        s1.hits + total,
        "every submission of the second trace replays"
    );
}
