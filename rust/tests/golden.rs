//! E8 — end-to-end golden validation: the cycle-accurate cluster's
//! functional output vs the AOT-compiled JAX/Pallas model executed
//! through PJRT (rust `xla` crate, CPU client).
//!
//! Compiled only with `--features xla` (the `xla` crate is unavailable
//! offline), and each test skips gracefully — with a message — when
//! the AOT artifacts have not been built (`make artifacts`).
#![cfg(feature = "xla")]

use zerostall::cluster::ConfigId;
use zerostall::kernels::{run_matmul, test_matrices};
use zerostall::runtime::{golden_matmul, max_rel_error, Runtime};

/// `None` (= skip the test) when the artifacts are absent.
fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "skipping golden test: artifacts not built (run `make \
             artifacts`; looked in {})",
            dir.display()
        );
        return None;
    }
    Some(Runtime::new(dir).expect("PJRT runtime init"))
}

#[test]
fn golden_cube_sizes() {
    let Some(rt) = runtime() else { return };
    for s in [8usize, 16, 32, 64] {
        let (a, b) = test_matrices(s, s, s, 21);
        let sim =
            run_matmul(ConfigId::Zonl48Db, s, s, s, &a, &b).unwrap();
        let gold = golden_matmul(&rt, s, s, s, &a, &b).unwrap();
        let err = max_rel_error(&sim.c, &gold);
        assert!(err < 1e-9, "{s}^3: rel err {err:.2e}");
    }
}

#[test]
fn golden_rectangular_padded() {
    // Sizes that are not multiples of the 32-wide golden tile: the
    // zero-padding composition path.
    let Some(rt) = runtime() else { return };
    for (m, n, k) in [(24, 40, 8), (8, 8, 72), (56, 16, 48)] {
        let (a, b) = test_matrices(m, n, k, 22);
        let sim =
            run_matmul(ConfigId::Zonl64Db, m, n, k, &a, &b).unwrap();
        let gold = golden_matmul(&rt, m, n, k, &a, &b).unwrap();
        let err = max_rel_error(&sim.c, &gold);
        assert!(err < 1e-9, "{m}x{n}x{k}: rel err {err:.2e}");
    }
}

#[test]
fn golden_all_configs_agree() {
    let Some(rt) = runtime() else { return };
    let (m, n, k) = (32, 32, 32);
    let (a, b) = test_matrices(m, n, k, 23);
    let gold = golden_matmul(&rt, m, n, k, &a, &b).unwrap();
    for id in ConfigId::all() {
        let sim = run_matmul(id, m, n, k, &a, &b).unwrap();
        let err = max_rel_error(&sim.c, &gold);
        assert!(err < 1e-9, "{}: rel err {err:.2e}", id.name());
    }
}

#[test]
fn plain_artifact_executes() {
    // The non-accumulating 32^3 artifact (quickstart path).
    let Some(rt) = runtime() else { return };
    let art = rt.load("matmul_32").unwrap();
    let (a, b) = test_matrices(32, 32, 32, 24);
    let c = art
        .run_f64(&[(&a, &[32, 32]), (&b, &[32, 32])])
        .unwrap();
    // sanity vs golden composition
    let gold = golden_matmul(&rt, 32, 32, 32, &a, &b).unwrap();
    let err = max_rel_error(&c, &gold);
    assert!(err < 1e-12, "artifact mismatch {err:.2e}");
}

#[test]
fn pallas_lowered_full_size_artifact() {
    // matmul_128 is the Pallas-tiled (L1 kernel) lowering: proves the
    // pallas kernel + jax grid compose into one executable module.
    let Some(rt) = runtime() else { return };
    let art = rt.load("matmul_128").unwrap();
    let (a, b) = test_matrices(128, 128, 128, 25);
    let c = art
        .run_f64(&[(&a, &[128, 128]), (&b, &[128, 128])])
        .unwrap();
    let gold = golden_matmul(&rt, 128, 128, 128, &a, &b).unwrap();
    let err = max_rel_error(&c, &gold);
    assert!(err < 1e-11, "pallas artifact mismatch {err:.2e}");
}
