//! E8 — end-to-end golden validation: the cycle-accurate cluster's
//! functional output vs the AOT-compiled JAX/Pallas model executed
//! through PJRT (rust `xla` crate, CPU client).
//!
//! Requires `make artifacts` (the build system runs it before
//! `cargo test`); tests fail with a clear message otherwise.

use zerostall::cluster::ConfigId;
use zerostall::kernels::{run_matmul, test_matrices};
use zerostall::runtime::{golden_matmul, max_rel_error, Runtime};

fn runtime() -> Runtime {
    Runtime::new(Runtime::default_dir()).expect(
        "artifacts missing — run `make artifacts` before cargo test",
    )
}

#[test]
fn golden_cube_sizes() {
    let rt = runtime();
    for s in [8usize, 16, 32, 64] {
        let (a, b) = test_matrices(s, s, s, 21);
        let sim =
            run_matmul(ConfigId::Zonl48Db, s, s, s, &a, &b).unwrap();
        let gold = golden_matmul(&rt, s, s, s, &a, &b).unwrap();
        let err = max_rel_error(&sim.c, &gold);
        assert!(err < 1e-9, "{s}^3: rel err {err:.2e}");
    }
}

#[test]
fn golden_rectangular_padded() {
    // Sizes that are not multiples of the 32-wide golden tile: the
    // zero-padding composition path.
    let rt = runtime();
    for (m, n, k) in [(24, 40, 8), (8, 8, 72), (56, 16, 48)] {
        let (a, b) = test_matrices(m, n, k, 22);
        let sim =
            run_matmul(ConfigId::Zonl64Db, m, n, k, &a, &b).unwrap();
        let gold = golden_matmul(&rt, m, n, k, &a, &b).unwrap();
        let err = max_rel_error(&sim.c, &gold);
        assert!(err < 1e-9, "{m}x{n}x{k}: rel err {err:.2e}");
    }
}

#[test]
fn golden_all_configs_agree() {
    let rt = runtime();
    let (m, n, k) = (32, 32, 32);
    let (a, b) = test_matrices(m, n, k, 23);
    let gold = golden_matmul(&rt, m, n, k, &a, &b).unwrap();
    for id in ConfigId::all() {
        let sim = run_matmul(id, m, n, k, &a, &b).unwrap();
        let err = max_rel_error(&sim.c, &gold);
        assert!(err < 1e-9, "{}: rel err {err:.2e}", id.name());
    }
}

#[test]
fn plain_artifact_executes() {
    // The non-accumulating 32^3 artifact (quickstart path).
    let rt = runtime();
    let art = rt.load("matmul_32").unwrap();
    let (a, b) = test_matrices(32, 32, 32, 24);
    let c = art
        .run_f64(&[(&a, &[32, 32]), (&b, &[32, 32])])
        .unwrap();
    // sanity vs golden composition
    let gold = golden_matmul(&rt, 32, 32, 32, &a, &b).unwrap();
    let err = max_rel_error(&c, &gold);
    assert!(err < 1e-12, "artifact mismatch {err:.2e}");
}

#[test]
fn pallas_lowered_full_size_artifact() {
    // matmul_128 is the Pallas-tiled (L1 kernel) lowering: proves the
    // pallas kernel + jax grid compose into one executable module.
    let rt = runtime();
    let art = rt.load("matmul_128").unwrap();
    let (a, b) = test_matrices(128, 128, 128, 25);
    let c = art
        .run_f64(&[(&a, &[128, 128]), (&b, &[128, 128])])
        .unwrap();
    let gold = golden_matmul(&rt, 128, 128, 128, &a, &b).unwrap();
    let err = max_rel_error(&c, &gold);
    assert!(err < 1e-11, "pallas artifact mismatch {err:.2e}");
}
