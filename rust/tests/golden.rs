//! Golden tests.
//!
//! * [`serve_golden`] — always-on: pins the ServeSim summary for one
//!   small zoo model at a fixed seed (request count, total cycles,
//!   p99 bucket, CSV schema, report phrasing) against an
//!   *independent reconstruction* of the expected accounting, so
//!   report-format or accounting drift is caught without a committed
//!   snapshot going stale.
//! * [`pjrt`] — E8, the original end-to-end functional golden: the
//!   cycle-accurate cluster vs the AOT-compiled JAX/Pallas model
//!   executed through PJRT. Compiled only with `--features xla` (the
//!   `xla` crate is unavailable offline), and each test skips
//!   gracefully — with a message — when the AOT artifacts have not
//!   been built (`make artifacts`).

mod serve_golden {
    use zerostall::coordinator::net::add_pass_cycles;
    use zerostall::coordinator::report;
    use zerostall::coordinator::serve::{
        gen_arrivals, serve, Policy, ServeConfig,
    };
    use zerostall::coordinator::workload::{zoo, NetOp};
    use zerostall::kernels::{GemmJob, GemmService, LayoutKind};
    use zerostall::util::stats::CycleHistogram;

    /// The pinned scenario: one `ffn` request, FIFO, one cluster,
    /// analytic backend, fixed seed.
    fn pinned_cfg() -> ServeConfig {
        let mut cfg = ServeConfig::new(vec!["ffn".to_string()]);
        cfg.policy = Policy::Fifo;
        cfg.clusters = 1;
        cfg.requests = 1;
        cfg.seed = 0x60D5;
        cfg.slo = Some(u64::MAX);
        cfg.threads = 1;
        cfg
    }

    #[test]
    fn serve_summary_matches_independent_reconstruction() {
        let cfg = pinned_cfg();
        let svc = GemmService::analytic();
        let run = serve(&svc, &cfg).unwrap();
        let r = &run.report;

        // Request count pinned.
        assert_eq!(r.requests, 1);
        assert_eq!(r.completed, 1);
        let trace = gen_arrivals(&cfg);
        assert_eq!(trace.requests.len(), 1);
        assert_eq!(
            trace.requests[0].arrival, 0,
            "the first arrival is always cycle 0"
        );

        // Total cycles pinned against an independent reconstruction:
        // FIFO on one cluster serializes the ffn chain, so the
        // makespan is exactly the sum of the per-op backend costs —
        // any double counting, dropped op, or cost-model drift in the
        // serve accounting breaks this equality.
        let g = zoo::build("ffn").unwrap();
        let probe = GemmService::analytic();
        let mut expect = 0u64;
        for op in &g.ops {
            match op {
                NetOp::Gemm { x, w, epi, .. } => {
                    let (xt, wt) = (&g.tensors[*x], &g.tensors[*w]);
                    let job = GemmJob::fused(
                        cfg.config,
                        xt.rows,
                        wt.cols,
                        xt.cols,
                        LayoutKind::Grouped,
                        *epi,
                    );
                    expect += probe.run_job(&job).unwrap().cycles;
                }
                NetOp::Add { out, .. } => {
                    expect += add_pass_cycles(g.tensors[*out].elems());
                }
            }
        }
        assert!(expect > 0);
        assert_eq!(
            r.makespan_cycles, expect,
            "total-cycle accounting drifted"
        );
        assert_eq!(r.latency.max(), expect);
        assert_eq!(r.p50(), r.p99(), "one request: p50 == p99");

        // p99 bucket pinned: the reported percentile must land in the
        // same histogram bucket as the reconstructed latency.
        assert_eq!(
            CycleHistogram::bucket_index(r.p99()),
            CycleHistogram::bucket_index(expect),
            "p99 bucket drifted (p99 {}, expected latency {expect})",
            r.p99()
        );

        // Per-cluster accounting: one cluster, busy the whole chain.
        assert_eq!(r.per_cluster_busy, vec![expect]);
        assert_eq!(r.slo_attained, 1);

        // CSV schema pinned.
        assert_eq!(run.rows.len(), 1);
        let csv = report::serve_csv(&run).to_string();
        assert!(
            csv.starts_with(
                "req,model,arrival,completion,latency_cycles,slo_met,ops\n"
            ),
            "CSV schema drifted:\n{csv}"
        );
        assert!(csv.contains(&format!("0,ffn,0,{expect},{expect},1,3")));

        // Report phrasing pinned (format drift).
        let doc = report::render_serve(r);
        for needle in [
            "## Serve `ffn`",
            "policy `fifo`",
            "sustained",
            "latency cycles: p50",
            "SLO",
            "attained",
            "plan cache:",
            "hit rate under churn",
        ] {
            assert!(
                doc.contains(needle),
                "report format drifted; missing `{needle}` in:\n{doc}"
            );
        }
    }

    #[test]
    fn serve_golden_is_stable_across_reruns() {
        // The pinned scenario replays bit-for-bit on fresh services.
        let cfg = pinned_cfg();
        let a = serve(&GemmService::analytic(), &cfg).unwrap();
        let b = serve(&GemmService::analytic(), &cfg).unwrap();
        assert_eq!(a, b);
        assert_eq!(
            report::render_serve(&a.report),
            report::render_serve(&b.report)
        );
    }
}

mod node_golden {
    use zerostall::coordinator::node::{
        run_digest, run_node, NodeConfig, NodeRow, RouterPolicy,
    };
    use zerostall::coordinator::report;
    use zerostall::coordinator::serve::{
        gen_arrivals, solo_latency, Policy, ServeConfig,
    };
    use zerostall::kernels::GemmService;
    use zerostall::util::stats::CycleHistogram;

    /// The pinned scenario: six `ffn` requests round-robined over two
    /// fabrics, analytic backend, fixed seed, no faults — small
    /// enough that the whole outcome is reconstructible by hand.
    fn pinned_cfg() -> NodeConfig {
        let mut serve = ServeConfig::new(vec!["ffn".to_string()]);
        serve.clusters = 2;
        serve.requests = 6;
        serve.rate_per_mcycle = 25.0;
        serve.seed = 0x90D5;
        serve.slo = Some(u64::MAX);
        let mut cfg = NodeConfig::new(serve, 2);
        cfg.router = RouterPolicy::RoundRobin;
        cfg
    }

    #[test]
    fn node_summary_matches_independent_reconstruction() {
        let cfg = pinned_cfg();
        let svc = GemmService::analytic();
        let run = run_node(&svc, &cfg).unwrap();
        let r = &run.report;

        // Counts pinned: no faults, no admission control — every
        // arrival completes, nothing retries.
        assert_eq!(r.requests, 6);
        assert_eq!(r.completed, 6);
        assert_eq!(r.shed_total(), 0);
        assert_eq!(r.retries_total, 0);
        assert!(run.sheds.is_empty());

        // Independent reconstruction: the arrival trace is public,
        // the service cost is a fresh probe through the serve engine,
        // round-robin over two always-up fabrics is `id % 2`, and
        // each fabric is a serial queue, so completions follow the
        // Lindley recurrence per fabric. Any drift in routing,
        // queueing, or cost accounting breaks this equality.
        let probe = GemmService::analytic();
        let cost =
            solo_latency(&probe, &cfg.serve, 0, Policy::Continuous)
                .unwrap();
        assert!(cost > 0);
        let trace = gen_arrivals(&cfg.serve);
        let mut free = [0u64; 2];
        let mut expect_rows = Vec::new();
        for req in &trace.requests {
            let fabric = req.id % 2;
            let dispatched = req.arrival.max(free[fabric]);
            let completion = dispatched + cost;
            free[fabric] = completion;
            expect_rows.push(NodeRow {
                id: req.id,
                model: 0,
                session: req.seed % cfg.sessions as u64,
                fabric,
                arrival: req.arrival,
                dispatched,
                completion,
                latency: completion - req.arrival,
                retries: 0,
                slo_met: true,
            });
        }
        assert_eq!(run.rows, expect_rows, "outcome rows drifted");
        assert_eq!(r.makespan_cycles, free[0].max(free[1]));

        // The digest is exactly the FNV fold of the public outcome
        // streams — recomputed here from the reconstruction.
        assert_eq!(
            run_digest(&expect_rows, &[]),
            r.digest,
            "run digest no longer folds (id, completion, fabric, \
             retries)"
        );

        // p99 pinned against a reconstructed histogram.
        let mut hist = CycleHistogram::new();
        for row in &expect_rows {
            hist.record(row.latency);
        }
        assert_eq!(r.p99(), hist.quantile(0.99), "p99 drifted");
        assert_eq!(r.slo_attained, 6);

        // CSV schemas pinned.
        let csv = report::node_csv(&run).to_string();
        assert!(
            csv.starts_with(
                "req,model,session,fabric,arrival,dispatched,\
                 completion,latency_cycles,retries,slo_met\n"
            ),
            "node CSV schema drifted:\n{csv}"
        );
        assert_eq!(csv.lines().count(), 1 + 6);
        let first = &expect_rows[0];
        assert!(csv.contains(&format!(
            "0,ffn,{},0,{},{},{},{},0,1",
            first.session,
            first.arrival,
            first.dispatched,
            first.completion,
            first.latency,
        )));
        let sheds = report::node_sheds_csv(&run).to_string();
        assert!(
            sheds.starts_with(
                "req,model,session,arrival,shed_at,retries,reason\n"
            ),
            "shed CSV schema drifted:\n{sheds}"
        );
        assert_eq!(sheds.lines().count(), 1, "shed CSV must be empty");
        let fab = report::node_fabric_csv(r).to_string();
        assert!(
            fab.starts_with(
                "fabric,served,busy_cycles,utilization,lost_cycles,\
                 downtime,p50,p99\n"
            ),
            "fabric CSV schema drifted:\n{fab}"
        );
        assert_eq!(fab.lines().count(), 1 + 2);
        assert!(fab.contains(&format!("0,3,{},", 3 * cost)));

        // Report phrasing pinned.
        let doc = report::render_node(r);
        for needle in [
            "## Node serve `ffn`",
            "router `rr`, 2 fabrics x 2 clusters",
            "* fault plan: none (max retries 3)",
            "* shed: 0 (0 admission / 0 retry-budget / 0 unroutable)",
            "* run digest: 0x",
            "* service cost model (cycles/request):",
            "  * fabric 1: served 3,",
        ] {
            assert!(
                doc.contains(needle),
                "node report drifted; missing `{needle}` in:\n{doc}"
            );
        }
        assert!(doc
            .contains(&format!("run digest: 0x{:016x}", r.digest)));
    }

    #[test]
    fn node_golden_is_stable_across_reruns() {
        let cfg = pinned_cfg();
        let a = run_node(&GemmService::analytic(), &cfg).unwrap();
        let b = run_node(&GemmService::analytic(), &cfg).unwrap();
        assert_eq!(a, b);
        assert_eq!(
            report::render_node(&a.report),
            report::render_node(&b.report)
        );
    }
}

mod timescope_golden {
    use zerostall::coordinator::node::{
        run_digest, run_node, NodeConfig, RouterPolicy,
    };
    use zerostall::coordinator::report;
    use zerostall::coordinator::serve::{
        gen_arrivals, solo_latency, Policy, ServeConfig,
    };
    use zerostall::kernels::GemmService;
    use zerostall::util::stats::Fnv64;

    /// The node-golden scenario with telemetry on: six `ffn` requests
    /// round-robined over two fabrics, window = one service cost, so
    /// every windowed series is reconstructible from the same Lindley
    /// recurrence the node golden pins.
    fn pinned_cfg(window: u64) -> NodeConfig {
        let mut serve = ServeConfig::new(vec!["ffn".to_string()]);
        serve.clusters = 2;
        serve.requests = 6;
        serve.rate_per_mcycle = 25.0;
        serve.seed = 0x90D5;
        serve.slo = Some(u64::MAX);
        serve.telemetry = Some(window);
        let mut cfg = NodeConfig::new(serve, 2);
        cfg.router = RouterPolicy::RoundRobin;
        cfg
    }

    #[test]
    fn telemetry_csv_schema_and_window_rows_are_pinned() {
        let svc = GemmService::analytic();
        let probe_cfg = pinned_cfg(1);
        let cost =
            solo_latency(&svc, &probe_cfg.serve, 0, Policy::Continuous)
                .unwrap();
        assert!(cost > 0);
        let w = cost;
        let cfg = pinned_cfg(w);
        let run = run_node(&svc, &cfg).unwrap();
        let tel = run.telemetry.as_ref().expect("telemetry enabled");
        assert_eq!(tel.window(), w);

        // Independent reconstruction of the windowed series from the
        // public arrival trace (round-robin is `id % 2`, each fabric
        // a serial queue).
        let trace = gen_arrivals(&cfg.serve);
        let mut free = [0u64; 2];
        let mut completions0 =
            std::collections::BTreeMap::<u64, u64>::new();
        let mut arrivals_w0 = 0u64;
        for req in &trace.requests {
            if req.arrival < w {
                arrivals_w0 += 1;
            }
            let fabric = (req.id % 2) as usize;
            let dispatched = req.arrival.max(free[fabric]);
            let completion = dispatched + cost;
            free[fabric] = completion;
            if fabric == 0 {
                *completions0.entry(completion / w).or_insert(0) += 1;
            }
        }
        assert!(arrivals_w0 > 0, "first arrival is cycle 0");

        // CSV schema pinned.
        let csv = report::telemetry_csv(tel).to_string();
        assert!(
            csv.starts_with(
                "metric,labels,window,t_start,t_end,kind,value\n"
            ),
            "telemetry CSV schema drifted:\n{csv}"
        );
        // Window-0 arrivals row reconstructed exactly.
        assert!(
            csv.contains(&format!(
                "arrivals,,0,0,{w},count,{arrivals_w0}"
            )),
            "window-0 arrivals row drifted:\n{csv}"
        );
        // First fabric-0 completion window reconstructed exactly.
        let (&k0, &n0) = completions0.iter().next().unwrap();
        assert!(
            csv.contains(&format!(
                "completions,fabric=0,{k0},{},{},count,{n0}",
                k0 * w,
                (k0 + 1) * w,
            )),
            "fabric-0 completion window row drifted:\n{csv}"
        );
        // Counter series are dense: one row per window, so a stalled
        // window is an explicit zero row, not a missing one.
        let arrival_rows = csv
            .lines()
            .filter(|l| l.starts_with("arrivals,,"))
            .count() as u64;
        assert_eq!(arrival_rows, tel.last_window() + 1);
        // The artifact itself conserves busy cycles: fabric 0 served
        // three requests back to back.
        let busy_sum: u64 = csv
            .lines()
            .filter(|l| l.starts_with("fabric_busy_cycles,fabric=0,"))
            .map(|l| l.rsplit(',').next().unwrap().parse::<u64>().unwrap())
            .sum();
        assert_eq!(busy_sum, 3 * cost, "busy-cycle rows drifted");

        // The run digest is the base outcome digest with the
        // registry folded on top.
        let mut h = Fnv64::new();
        h.write_u64(run_digest(&run.rows, &run.sheds));
        tel.fold(&mut h);
        assert_eq!(run.report.digest, h.finish());

        // Report phrasing pinned; no autoscale line when off.
        let doc = report::render_telemetry(tel);
        for needle in [
            "### TimeScope telemetry",
            "* window:",
            "stream digest 0x",
        ] {
            assert!(
                doc.contains(needle),
                "telemetry report drifted; missing `{needle}` in:\n{doc}"
            );
        }
        assert!(!doc.contains("autoscale:"));
        let node_doc = report::render_node(&run.report);
        assert!(!node_doc.contains("autoscale"));
    }
}

mod stallscope_golden {
    use zerostall::coordinator::profile::{run_profile, ProfileOpts};
    use zerostall::coordinator::report;

    /// Pins the StallScope artifact schemas (stall-breakdown and
    /// roofline CSVs) and the conservation invariant on one small
    /// pinned scenario — schema drift breaks downstream tooling
    /// silently, so it must break here loudly instead.
    #[test]
    fn profile_csv_schemas_are_pinned() {
        let opts = ProfileOpts::new("qkv");
        let (rep, _) = run_profile(&opts).unwrap();
        rep.merged.check_conservation().unwrap();

        let stalls = report::stall_csv(&rep).to_string();
        assert!(
            stalls.starts_with(
                "layer,core,cycles,useful,control_overhead,\
                 ssr_operand_wait,raw_hazard,bank_conflict,dma_wait,\
                 barrier,noc_gated,drain\n"
            ),
            "stall CSV schema drifted:\n{stalls}"
        );
        // One row per profiled core per layer (8 compute + 1 DM).
        assert_eq!(
            stalls.lines().count(),
            1 + rep.layers.len() * 9,
            "row count drifted:\n{stalls}"
        );
        assert!(stalls.contains("qkv_proj,c0,"));
        assert!(stalls.contains("qkv_proj,dm0,"));

        let points: Vec<_> =
            rep.layers.iter().map(|l| l.roofline.clone()).collect();
        let roof = report::roofline_csv(&points).to_string();
        assert!(
            roof.starts_with(
                "layer,ops,bytes,oi_ops_per_byte,\
                 attained_ops_per_cycle,roof_ops_per_cycle,\
                 attainment,bound\n"
            ),
            "roofline CSV schema drifted:\n{roof}"
        );
        assert_eq!(roof.lines().count(), 1 + rep.layers.len());
        // The qkv projection is a dense compute-bound GEMM.
        assert!(roof.contains("qkv_proj"));
        assert!(
            roof.trim_end().ends_with("compute"),
            "qkv must place compute-bound:\n{roof}"
        );

        // Report phrasing pinned.
        let doc = report::render_profile(&rep);
        for needle in [
            "## StallScope profile",
            "Merged stall breakdown",
            "conservation: OK",
            "### Roofline",
            "| Useful |",
            "| BankConflict |",
        ] {
            assert!(
                doc.contains(needle),
                "profile report drifted; missing `{needle}` in:\n{doc}"
            );
        }
    }
}

mod proofscope_golden {
    use zerostall::coordinator::lint::{run_lint, LintOpts};
    use zerostall::coordinator::report;

    /// Pins the ProofScope artifact schemas (verdict and theorem
    /// CSVs) and the lint report phrasing on one small static-only
    /// scenario.
    #[test]
    fn lint_csv_schemas_are_pinned() {
        let mut opts = LintOpts::new("qkv");
        opts.gate = false;
        let rep = run_lint(&opts).unwrap();

        let csv = report::lint_csv(&rep).to_string();
        assert!(
            csv.starts_with(
                "model,layer,m,n,k,config,clusters,shards,class,\
                 verdict,bound,measured_cycle_ff,measured_cycle,\
                 measured_analytic,gate\n"
            ),
            "lint CSV schema drifted:\n{csv}"
        );
        // One row per layer per stall class.
        assert_eq!(
            csv.lines().count(),
            1 + rep.layers.len() * 9,
            "row count drifted:\n{csv}"
        );
        assert!(csv.contains("qkv,qkv_proj,64,192,64,zonl48db,1,1,"));
        assert!(csv.contains(",raw_hazard,impossible,"));
        assert!(csv.contains(",bank_conflict,bounded,"));

        let th = report::lint_theorems_csv(&rep).to_string();
        assert!(
            th.starts_with("model,layer,theorem,holds,detail\n"),
            "theorem CSV schema drifted:\n{th}"
        );
        assert!(th.contains(",dma_phase_disjoint,1,"));
        assert!(th.contains(",zonl_zero_loop_overhead,1,"));

        // Report phrasing pinned.
        let doc = report::render_lint(&rep);
        for needle in [
            "## ProofScope lint",
            "proved impossible",
            "| RawHazard |",
            "### Theorems",
            "zonl_zero_loop_overhead",
            "static verdicts only",
        ] {
            assert!(
                doc.contains(needle),
                "lint report drifted; missing `{needle}` in:\n{doc}"
            );
        }
    }
}

#[cfg(feature = "xla")]
mod pjrt {
    use zerostall::cluster::ConfigId;
    use zerostall::kernels::{run_matmul, test_matrices};
    use zerostall::runtime::{golden_matmul, max_rel_error, Runtime};

    /// `None` (= skip the test) when the artifacts are absent.
    fn runtime() -> Option<Runtime> {
        let dir = Runtime::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!(
                "skipping golden test: artifacts not built (run `make \
                 artifacts`; looked in {})",
                dir.display()
            );
            return None;
        }
        Some(Runtime::new(dir).expect("PJRT runtime init"))
    }

    #[test]
    fn golden_cube_sizes() {
        let Some(rt) = runtime() else { return };
        for s in [8usize, 16, 32, 64] {
            let (a, b) = test_matrices(s, s, s, 21);
            let sim =
                run_matmul(ConfigId::Zonl48Db, s, s, s, &a, &b).unwrap();
            let gold = golden_matmul(&rt, s, s, s, &a, &b).unwrap();
            let err = max_rel_error(&sim.c, &gold);
            assert!(err < 1e-9, "{s}^3: rel err {err:.2e}");
        }
    }

    #[test]
    fn golden_rectangular_padded() {
        // Sizes that are not multiples of the 32-wide golden tile: the
        // zero-padding composition path.
        let Some(rt) = runtime() else { return };
        for (m, n, k) in [(24, 40, 8), (8, 8, 72), (56, 16, 48)] {
            let (a, b) = test_matrices(m, n, k, 22);
            let sim =
                run_matmul(ConfigId::Zonl64Db, m, n, k, &a, &b).unwrap();
            let gold = golden_matmul(&rt, m, n, k, &a, &b).unwrap();
            let err = max_rel_error(&sim.c, &gold);
            assert!(err < 1e-9, "{m}x{n}x{k}: rel err {err:.2e}");
        }
    }

    #[test]
    fn golden_all_configs_agree() {
        let Some(rt) = runtime() else { return };
        let (m, n, k) = (32, 32, 32);
        let (a, b) = test_matrices(m, n, k, 23);
        let gold = golden_matmul(&rt, m, n, k, &a, &b).unwrap();
        for id in ConfigId::all() {
            let sim = run_matmul(id, m, n, k, &a, &b).unwrap();
            let err = max_rel_error(&sim.c, &gold);
            assert!(err < 1e-9, "{}: rel err {err:.2e}", id.name());
        }
    }

    #[test]
    fn plain_artifact_executes() {
        // The non-accumulating 32^3 artifact (quickstart path).
        let Some(rt) = runtime() else { return };
        let art = rt.load("matmul_32").unwrap();
        let (a, b) = test_matrices(32, 32, 32, 24);
        let c = art
            .run_f64(&[(&a, &[32, 32]), (&b, &[32, 32])])
            .unwrap();
        // sanity vs golden composition
        let gold = golden_matmul(&rt, 32, 32, 32, &a, &b).unwrap();
        let err = max_rel_error(&c, &gold);
        assert!(err < 1e-12, "artifact mismatch {err:.2e}");
    }

    #[test]
    fn pallas_lowered_full_size_artifact() {
        // matmul_128 is the Pallas-tiled (L1 kernel) lowering: proves
        // the pallas kernel + jax grid compose into one executable
        // module.
        let Some(rt) = runtime() else { return };
        let art = rt.load("matmul_128").unwrap();
        let (a, b) = test_matrices(128, 128, 128, 25);
        let c = art
            .run_f64(&[(&a, &[128, 128]), (&b, &[128, 128])])
            .unwrap();
        let gold = golden_matmul(&rt, 128, 128, 128, &a, &b).unwrap();
        let err = max_rel_error(&c, &gold);
        assert!(err < 1e-11, "pallas artifact mismatch {err:.2e}");
    }
}
