//! Integration tests: whole-cluster behaviour across modules —
//! numerics, perf-counter conservation laws, the paper's structural
//! claims (E5-E7 of DESIGN.md) and failure handling.

use zerostall::backend::{Analytic, BackendKind, SimBackend};
use zerostall::cluster::{Cluster, ConfigId};
use zerostall::coordinator::experiments::{self, run_point};
use zerostall::coordinator::workload::Problem;
use zerostall::isa::asm::Asm;
use zerostall::isa::Instr;
use zerostall::kernels::{
    host_ref, run_matmul, run_matmul_layout, test_matrices, GemmJob,
    GemmService, LayoutKind,
};
use zerostall::model::energy;

fn assert_numerics(id: ConfigId, m: usize, n: usize, k: usize) {
    let (a, b) = test_matrices(m, n, k, 5);
    let r = run_matmul(id, m, n, k, &a, &b).unwrap();
    let want = host_ref(m, n, k, &a, &b);
    for (i, (g, w)) in r.c.iter().zip(&want).enumerate() {
        assert!(
            (g - w).abs() <= 1e-9 * w.abs().max(1.0),
            "{} {m}x{n}x{k} C[{i}]: {g} vs {w}",
            id.name()
        );
    }
}

#[test]
fn size_battery_zonl48db() {
    for (m, n, k) in [
        (8, 8, 8),
        (8, 128, 8),
        (128, 8, 8),
        (8, 8, 128),
        (24, 40, 56),
        (120, 16, 88),
        (64, 64, 64),
    ] {
        assert_numerics(ConfigId::Zonl48Db, m, n, k);
    }
}

#[test]
fn size_battery_baseline() {
    for (m, n, k) in [(8, 8, 8), (48, 24, 72), (64, 64, 64)] {
        assert_numerics(ConfigId::Base32Fc, m, n, k);
    }
}

#[test]
fn all_configs_bitwise_identical_results() {
    // Same kernel structure + same association order => all five
    // configurations must produce exactly the same C matrix.
    let (m, n, k) = (40, 32, 24);
    let (a, b) = test_matrices(m, n, k, 6);
    let first = run_matmul(ConfigId::Base32Fc, m, n, k, &a, &b)
        .unwrap()
        .c;
    for id in &ConfigId::all()[1..] {
        let c = run_matmul(*id, m, n, k, &a, &b).unwrap().c;
        assert_eq!(
            first, c,
            "{} differs bitwise from base32fc",
            id.name()
        );
    }
}

#[test]
fn fpu_op_conservation() {
    // One FPU instruction per MAC, across every config and layout.
    let (m, n, k) = (32, 64, 40);
    let (a, b) = test_matrices(m, n, k, 7);
    for id in ConfigId::all() {
        for layout in
            [LayoutKind::Grouped, LayoutKind::Linear { pad_words: 0 }]
        {
            let r =
                run_matmul_layout(id, m, n, k, &a, &b, layout).unwrap();
            assert_eq!(
                r.perf.fpu_ops_total,
                (m * n * k) as u64,
                "{} {:?}",
                id.name(),
                layout
            );
        }
    }
}

#[test]
fn dma_byte_conservation() {
    // The DMA must move exactly: A once per (it) x grid_n, B once per
    // pass, C out once.
    let (m, n, k) = (64, 64, 64);
    let (a, b) = test_matrices(m, n, k, 8);
    let r = run_matmul(ConfigId::Zonl48Db, m, n, k, &a, &b).unwrap();
    let t = r.plan.tiling;
    let passes = t.passes() as u64;
    let expect = passes * (t.mt * t.k + t.k * t.nt) as u64 * 8
        + passes * (t.mt * t.nt) as u64 * 8;
    assert_eq!(r.perf.dma_bytes, expect);
}

#[test]
fn zero_dma_conflicts_on_dobu_configs() {
    // E7: the zero-conflict memory subsystem claim — multi-pass
    // problem so the DMA is busy during compute.
    let (m, n, k) = (96, 96, 96);
    let (a, b) = test_matrices(m, n, k, 9);
    for id in [ConfigId::Zonl48Db, ConfigId::Zonl64Db, ConfigId::Zonl64Fc]
    {
        let r = run_matmul(id, m, n, k, &a, &b).unwrap();
        assert_eq!(
            r.perf.tcdm_conflicts_dma,
            0,
            "{}: DMA-induced conflicts present",
            id.name()
        );
    }
    // ... while the 32-bank configs do suffer them.
    let rb = run_matmul(ConfigId::Base32Fc, m, n, k, &a, &b).unwrap();
    assert!(
        rb.perf.tcdm_conflicts_dma > 0,
        "base32fc should see DMA conflicts"
    );
}

#[test]
fn utilization_ordering_multi_pass() {
    // E5 structure on a multi-pass problem.
    let p = Problem { m: 96, n: 64, k: 80 };
    let u = |id| {
        run_point(id, p, LayoutKind::Grouped).unwrap().utilization
    };
    let base = u(ConfigId::Base32Fc);
    let z32 = u(ConfigId::Zonl32Fc);
    let z48 = u(ConfigId::Zonl48Db);
    assert!(z32 > base, "zonl32 {z32:.3} <= base {base:.3}");
    assert!(z48 > z32, "z48 {z48:.3} <= z32 {z32:.3}");
    assert!(z48 > 0.96, "z48 {z48:.3} below the paper's band");
}

#[test]
fn grouped_layout_beats_linear_on_dobu() {
    let p = Problem { m: 64, n: 64, k: 64 };
    let g = run_point(ConfigId::Zonl48Db, p, LayoutKind::Grouped)
        .unwrap();
    let l = run_point(
        ConfigId::Zonl48Db,
        p,
        LayoutKind::Linear { pad_words: 0 },
    )
    .unwrap();
    assert!(
        g.utilization > l.utilization,
        "grouped {:.3} vs linear {:.3}",
        g.utilization,
        l.utilization
    );
}

#[test]
fn energy_model_fig5_relations() {
    // zonl64fc must pay interconnect energy; dobu must not.
    let p = Problem { m: 64, n: 64, k: 64 };
    let eff = |id| {
        let r = run_point(id, p, LayoutKind::Grouped).unwrap();
        r.gflops_per_w
    };
    let fc64 = eff(ConfigId::Zonl64Fc);
    let db64 = eff(ConfigId::Zonl64Db);
    let db48 = eff(ConfigId::Zonl48Db);
    let base = eff(ConfigId::Base32Fc);
    assert!(db64 > fc64, "dobu {db64:.2} <= fc {fc64:.2}");
    assert!(db48 > base, "48db {db48:.2} <= base {base:.2}");
}

#[test]
fn table2_energy_efficiency_story() {
    let rows = experiments::table2().unwrap();
    let ours = rows.iter().find(|r| r.name.contains("ours")).unwrap();
    let snitch =
        rows.iter().find(|r| r.name.contains("snitch")).unwrap();
    let og = rows.iter().find(|r| r.name.contains("opengemm")).unwrap();
    // comparable utilization and performance to the accelerator
    assert!(ours.utilization >= og.utilization - 0.01);
    assert!(ours.perf_gflops >= og.perf_gflops - 0.1);
    // we improve on the baseline, the accelerator still wins energy
    assert!(ours.energy_eff > snitch.energy_eff);
    assert!(og.energy_eff > ours.energy_eff);
    let gap = (og.energy_eff - ours.energy_eff) / og.energy_eff;
    assert!(gap < 0.20, "energy gap {gap:.2} (paper: 12%)");
}

#[test]
fn deadlock_detector_fires() {
    // Cores 1..8 wait at a barrier while core 0 spins forever: the
    // barrier can never release and run() must error out, not hang.
    // (A *halted* core counts as arrived — that is the documented
    // barrier semantics — so the spin loop is the real deadlock.)
    let cfg = ConfigId::Base32Fc.cluster_config();
    let mut progs = Vec::new();
    let mut spin = Asm::new();
    let top = spin.label();
    spin.bind(top);
    spin.jal(0, top); // while(1);
    progs.push(spin.assemble());
    for _ in 1..9 {
        let mut a = Asm::new();
        a.push(Instr::Barrier);
        a.push(Instr::Ecall);
        progs.push(a.assemble());
    }
    let mut cl = Cluster::new(cfg, progs);
    let res = cl.run(50_000);
    assert!(res.is_err(), "deadlock must be detected");
}

#[test]
fn halted_core_does_not_block_barrier() {
    // The complementary semantics check: a core that halts early does
    // not deadlock the rest of the cluster.
    let cfg = ConfigId::Base32Fc.cluster_config();
    let mut progs = Vec::new();
    let mut early = Asm::new();
    early.push(Instr::Ecall);
    progs.push(early.assemble());
    for _ in 1..9 {
        let mut a = Asm::new();
        a.push(Instr::Barrier);
        a.push(Instr::Ecall);
        progs.push(a.assemble());
    }
    let mut cl = Cluster::new(cfg, progs);
    let cycles = cl.run(10_000).unwrap();
    assert!(cycles < 100);
}

#[test]
fn window_cycles_consistency() {
    let (a, b) = test_matrices(32, 32, 32, 11);
    let r =
        run_matmul(ConfigId::Zonl48Db, 32, 32, 32, &a, &b).unwrap();
    assert!(r.perf.window_cycles > 0);
    assert!(r.perf.window_cycles <= r.cycles);
    assert!(r.utilization() <= 1.0);
    let e = energy(ConfigId::Zonl48Db, &r.perf);
    assert!(e.power.total_mw() > 250.0 && e.power.total_mw() < 500.0);
}

#[test]
fn service_cycle_backend_identical_to_driver() {
    // The SimBackend refactor is a pure re-plumbing of the run path:
    // the service + CycleAccurate must reproduce the driver's cycles,
    // perf counters, and output matrix exactly.
    let (m, n, k) = (40, 32, 24);
    let (a, b) = test_matrices(m, n, k, 31);
    let svc = GemmService::cycle();
    assert_eq!(svc.backend_kind(), BackendKind::Cycle);
    for id in ConfigId::all() {
        let drv = run_matmul(id, m, n, k, &a, &b).unwrap();
        let via =
            svc.run(id, m, n, k, LayoutKind::Grouped, &a, &b).unwrap();
        assert_eq!(drv.c, via.c, "{}: output differs", id.name());
        assert_eq!(drv.cycles, via.cycles, "{}", id.name());
        assert_eq!(
            drv.perf.window_cycles,
            via.perf.window_cycles,
            "{}",
            id.name()
        );
        assert_eq!(
            drv.perf.tcdm_conflicts,
            via.perf.tcdm_conflicts,
            "{}",
            id.name()
        );
    }
}

#[test]
fn service_batch_reuses_plans_across_threads() {
    let svc = GemmService::cycle();
    let jobs: Vec<GemmJob> = (0..6)
        .map(|_| {
            GemmJob::for_problem(
                ConfigId::Zonl48Db,
                16,
                16,
                16,
                LayoutKind::Grouped,
            )
        })
        .collect();
    let rows = svc.run_batch(&jobs, 3).unwrap();
    assert!(rows.windows(2).all(|w| w[0].cycles == w[1].cycles));
    let s = svc.stats();
    assert_eq!(s.plan_hits + s.plan_misses, 6);
    assert!(s.plan_hits >= 3, "cache must serve repeats: {s:?}");
}

#[test]
fn analytic_backend_orders_configs_like_cycle() {
    // The analytic model must reproduce the paper's structural
    // ordering (zonl48db ~ zonl64db > zonl32fc > base32fc) even with
    // the shipped default calibration.
    let svc = GemmService::analytic();
    let p = Problem { m: 96, n: 64, k: 80 };
    let u = |id| {
        experiments::run_point_with(&svc, id, p, LayoutKind::Grouped)
            .unwrap()
            .utilization
    };
    let base = u(ConfigId::Base32Fc);
    let z32 = u(ConfigId::Zonl32Fc);
    let z48 = u(ConfigId::Zonl48Db);
    assert!(z32 > base, "analytic: zonl32 {z32:.3} <= base {base:.3}");
    assert!(z48 >= z32, "analytic: z48 {z48:.3} < z32 {z32:.3}");
    assert!(z48 > 0.9, "analytic z48 {z48:.3} out of the paper's band");
}

#[test]
fn analytic_backend_runs_without_programs_or_data() {
    let svc = GemmService::analytic();
    let prep = svc
        .prepare(ConfigId::Zonl48Db, 64, 64, 64, LayoutKind::Grouped)
        .unwrap();
    assert!(
        prep.programs.is_empty(),
        "analytic preparation must skip codegen"
    );
    let backend = Analytic::default();
    assert!(!backend.needs_data() && !backend.needs_programs());
    let r = backend.run(&prep, &[], &[]).unwrap();
    assert!(r.c.is_empty());
    assert!(r.perf.window_cycles > 0);
    // DMA byte conservation holds for predictions too.
    let t = r.plan.tiling;
    let expect = t.passes() as u64
        * ((t.mt * t.k + t.k * t.nt + t.mt * t.nt) * 8) as u64;
    assert_eq!(r.perf.dma_bytes, expect);
}

#[test]
fn rb_replays_dominate_on_zonl() {
    // ZONL's energy story: instructions come from the ring buffer, not
    // the I$ (the §III-A energy argument).
    let (m, n, k) = (32, 32, 32);
    let (a, b) = test_matrices(m, n, k, 12);
    let z = run_matmul(ConfigId::Zonl48Db, m, n, k, &a, &b).unwrap();
    assert!(
        z.perf.rb_replays as f64
            > 0.9 * z.perf.fpu_ops_total as f64,
        "zonl should replay nearly all FP instrs from the RB: {} of {}",
        z.perf.rb_replays,
        z.perf.fpu_ops_total
    );
    // Baseline re-fetches the peeled rows from the I$ every iteration.
    let b_ = run_matmul(ConfigId::Base32Fc, m, n, k, &a, &b).unwrap();
    assert!(b_.perf.icache_fetches > 4 * z.perf.icache_fetches);
}
