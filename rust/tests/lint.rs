//! ProofScope differential soundness gate.
//!
//! Every zoo model runs through `zerostall lint` with the gate on:
//! the static verdicts (proved per plan by abstract interpretation of
//! the actual encoded programs) are checked against StallScope
//! measurements from the cycle engine with FastPath on, the cycle
//! engine with FastPath off, and the analytic predictor. A class
//! proved `Impossible` with a nonzero measurement — or `Bounded(n)`
//! with a measurement above `n` on a cycle source — is a soundness
//! bug in the analyzer or the machine model and fails here (and in
//! the CI smoke step that runs the same gate through the CLI).

use zerostall::coordinator::lint::{run_lint, LintOpts};
use zerostall::coordinator::workload::zoo;
use zerostall::profile::StallClass;
use zerostall::verify::{theorem, Verdict};

fn assert_gate(model: &str, clusters: usize) {
    let mut opts = LintOpts::new(model);
    opts.clusters = clusters;
    let rep = run_lint(&opts).unwrap();
    assert!(rep.gated);
    let fails = rep.failures();
    assert!(
        fails.is_empty(),
        "{model} x{clusters}: soundness gate violated: {fails:#?}"
    );
    for l in &rep.layers {
        // cycle+ff, cycle (naive stepping), analytic — all checked.
        assert_eq!(l.measured.len(), 3, "{model}/{}", l.name);
    }
}

#[test]
fn gate_mlp() {
    assert_gate("mlp", 1);
}

#[test]
fn gate_ffn() {
    assert_gate("ffn", 1);
}

#[test]
fn gate_qkv() {
    assert_gate("qkv", 1);
}

#[test]
fn gate_attn() {
    assert_gate("attn", 1);
}

#[test]
fn gate_conv() {
    assert_gate("conv", 1);
}

#[test]
fn gate_llm() {
    assert_gate("llm", 1);
}

#[test]
fn gate_qkv_sharded() {
    assert_gate("qkv", 2);
}

#[test]
fn gate_llm_sharded() {
    assert_gate("llm", 2);
}

/// The paper's zero-conflict claim, statically: on the Dobu config
/// every zoo kernel's DMA phases stay superbank-disjoint from the
/// streamed compute phase, loops carry zero overhead, and FPU RAW
/// hazards are impossible — for every plan the service would run.
#[test]
fn dobu_proves_the_paper_claims_across_the_zoo() {
    for model in zoo::models() {
        let mut opts = LintOpts::new(model);
        opts.gate = false;
        let rep = run_lint(&opts).unwrap();
        assert!(!rep.layers.is_empty(), "{model}");
        for l in &rep.layers {
            for name in [
                theorem::DMA_PHASE_DISJOINT,
                theorem::DOUBLE_BUFFER_RACE_FREE,
                theorem::ZONL_ZERO_LOOP_OVERHEAD,
                theorem::BARRIERS_MATCHED,
                theorem::CAPACITY_OK,
                theorem::REGION_SAFETY,
            ] {
                let t = l.report.theorem(name).unwrap();
                assert!(
                    t.holds,
                    "{model}/{}: {} does not hold: {}",
                    l.name, name, t.detail
                );
            }
            assert_eq!(
                l.report.verdict(StallClass::RawHazard),
                Verdict::Impossible,
                "{model}/{}",
                l.name
            );
            assert!(
                matches!(
                    l.report.verdict(StallClass::BankConflict),
                    Verdict::Bounded(_)
                ),
                "{model}/{}",
                l.name
            );
            assert!(
                matches!(
                    l.report.verdict(StallClass::ControlOverhead),
                    Verdict::Bounded(_)
                ),
                "{model}/{}",
                l.name
            );
        }
    }
}
