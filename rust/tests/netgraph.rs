//! NetGraph layer tests: DAG-scheduling properties (topological order,
//! no deadlock, shuffled-op robustness), cycle-backend bit-exactness
//! of network execution against sequential per-layer driver runs
//! (fused epilogues included), and the analytic-vs-cycle end-to-end
//! window error bound over the whole model zoo.

use std::collections::HashMap;

use zerostall::backend::{fit_calibration, CalSample};
use zerostall::cluster::ConfigId;
use zerostall::coordinator::net::{run_net, tensor_data};
use zerostall::coordinator::workload::graph::{NetGraph, NetOp, TensorKind};
use zerostall::coordinator::workload::zoo;
use zerostall::kernels::{Activation, GemmJob, GemmService, LayoutKind};
use zerostall::util::prop::{check, Config, Shrink};
use zerostall::util::rng::Rng;

// ==================================================================
// Random graph generator: layered MLP-ish DAGs with residual edges
// ==================================================================

/// Shrinkable carrier: (batch, layer dims, residual flags).
#[derive(Clone, Debug)]
struct GraphSpec {
    batch: usize,
    dims: Vec<usize>,
    residuals: Vec<bool>,
}

impl Shrink for GraphSpec {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.dims.len() > 2 {
            let mut s = self.clone();
            s.dims.pop();
            s.residuals.pop();
            out.push(s);
        }
        out
    }
}

fn gen_spec(rng: &mut Rng) -> GraphSpec {
    let n_layers = rng.range(1, 4);
    let batch = rng.range(1, 3) * 8;
    let dims: Vec<usize> =
        (0..=n_layers).map(|_| rng.range(1, 4) * 8).collect();
    let residuals = (0..n_layers).map(|_| rng.bool()).collect();
    GraphSpec { batch, dims, residuals }
}

fn build_graph(spec: &GraphSpec) -> NetGraph {
    let mut g = NetGraph::new("prop");
    let mut x = g.input("x", spec.batch, spec.dims[0]);
    for (i, win) in spec.dims.windows(2).enumerate() {
        let w = g.weight(&format!("w{i}"), win[0], win[1]);
        let b = g.bias(&format!("b{i}"), win[1]);
        let act = match i % 3 {
            0 => Some(Activation::Relu),
            1 => Some(Activation::Gelu),
            _ => None,
        };
        let y = g.gemm(&format!("fc{i}"), x, w, Some(b), act).unwrap();
        // residual only possible when shapes match
        x = if spec.residuals[i] && win[0] == win[1] {
            g.add(&format!("res{i}"), y, x).unwrap()
        } else {
            y
        };
    }
    g
}

/// Deterministically shuffle op order (ids stay valid — the scheduler
/// must not rely on topological list order).
fn shuffle_ops(g: &mut NetGraph, seed: u64) {
    let mut rng = Rng::new(seed);
    let n = g.ops.len();
    for i in (1..n).rev() {
        let j = rng.below(i as u64 + 1) as usize;
        g.ops.swap(i, j);
    }
}

#[test]
fn prop_dag_schedule_topological_and_deadlock_free() {
    check(
        &Config { cases: 40, seed: 0xDA6 },
        |rng| {
            let spec = gen_spec(rng);
            spec
        },
        |spec| {
            let mut g = build_graph(spec);
            shuffle_ops(&mut g, 0x5EED ^ spec.dims.len() as u64);
            g.topo_order().map_err(|e| e.to_string())?;
            let svc = GemmService::analytic();
            let run = run_net(
                &svc,
                &g,
                ConfigId::Zonl48Db,
                LayoutKind::Grouped,
                2,
                9,
            )
            .map_err(|e| e.to_string())?;
            // every op executed exactly once
            if run.report.layers.len() != g.ops.len() {
                return Err(format!(
                    "{} of {} ops executed",
                    run.report.layers.len(),
                    g.ops.len()
                ));
            }
            let mut seen = HashMap::new();
            for (pos, l) in run.report.layers.iter().enumerate() {
                if seen.insert(l.name.clone(), pos).is_some() {
                    return Err(format!("op {} ran twice", l.name));
                }
            }
            // topological order: every op runs after its producers
            let producer_of: HashMap<usize, &str> = g
                .ops
                .iter()
                .map(|op| (op.out(), op.name()))
                .collect();
            for op in &g.ops {
                let my_pos = seen[op.name()];
                for t in op.inputs() {
                    if let Some(p) = producer_of.get(&t) {
                        let p_pos = seen[*p];
                        if p_pos >= my_pos {
                            return Err(format!(
                                "{} (pos {my_pos}) ran before its \
                                 producer {} (pos {p_pos})",
                                op.name(),
                                p
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

// ==================================================================
// Cycle backend: network execution == sequential per-layer driver
// execution, bit for bit (epilogues included)
// ==================================================================

/// Sequential reference: execute ops one at a time in topological
/// order through the *driver* path, materializing tensors host-side.
fn sequential_reference(
    g: &NetGraph,
    config: ConfigId,
    seed: u64,
) -> HashMap<String, Vec<f64>> {
    let mut store: HashMap<usize, Vec<f64>> = HashMap::new();
    for (tid, t) in g.tensors.iter().enumerate() {
        if t.kind != TensorKind::Computed {
            store.insert(tid, tensor_data(seed, tid, t.elems()));
        }
    }
    for &i in &g.topo_order().unwrap() {
        match &g.ops[i] {
            NetOp::Gemm { x, w, bias, epi, out, .. } => {
                let (xt, wt) = (&g.tensors[*x], &g.tensors[*w]);
                let empty = Vec::new();
                let bias_data = match bias {
                    Some(b) => &store[b],
                    None => &empty,
                };
                let r = zerostall::kernels::run_matmul_fused(
                    config,
                    xt.rows,
                    wt.cols,
                    xt.cols,
                    *epi,
                    &store[x],
                    &store[w],
                    bias_data,
                )
                .unwrap();
                store.insert(*out, r.c);
            }
            NetOp::Add { a, b, out, .. } => {
                let sum: Vec<f64> = store[a]
                    .iter()
                    .zip(store[b].iter())
                    .map(|(x, y)| x + y)
                    .collect();
                store.insert(*out, sum);
            }
        }
    }
    g.outputs()
        .into_iter()
        .map(|tid| {
            (g.tensors[tid].name.clone(), store.remove(&tid).unwrap())
        })
        .collect()
}

#[test]
fn prop_cycle_net_matches_sequential_driver_bit_exact() {
    check(
        &Config { cases: 5, seed: 0xB17E },
        |rng| gen_spec(rng),
        |spec| {
            let mut g = build_graph(spec);
            shuffle_ops(&mut g, 0xACE);
            let seed = 31;
            let svc = GemmService::cycle();
            let run = run_net(
                &svc,
                &g,
                ConfigId::Zonl48Db,
                LayoutKind::Grouped,
                2,
                seed,
            )
            .map_err(|e| e.to_string())?;
            let want =
                sequential_reference(&g, ConfigId::Zonl48Db, seed);
            if run.outputs.len() != want.len() {
                return Err("output count mismatch".into());
            }
            for (name, got) in &run.outputs {
                let w = want
                    .get(name)
                    .ok_or_else(|| format!("missing output {name}"))?;
                if got != w {
                    return Err(format!(
                        "output {name} differs from sequential driver \
                         execution"
                    ));
                }
            }
            // fused layers add zero TCDM round-trips
            for l in &run.report.layers {
                if l.kind == "gemm" && l.extra_roundtrips != 0 {
                    return Err(format!(
                        "fused layer {} reports {} round-trips",
                        l.name, l.extra_roundtrips
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn zoo_llm_cycle_net_bit_exact_and_fully_fused() {
    let g = zoo::build("llm").unwrap();
    let seed = 2026;
    let svc = GemmService::cycle();
    let run = run_net(
        &svc,
        &g,
        ConfigId::Zonl48Db,
        LayoutKind::Grouped,
        4,
        seed,
    )
    .unwrap();
    let want = sequential_reference(&g, ConfigId::Zonl48Db, seed);
    for (name, got) in &run.outputs {
        assert_eq!(got, &want[name], "{name} differs");
    }
    // every GEMM layer is fused: zero extra round-trips from GEMMs
    let gemm_trips: u64 = run
        .report
        .layers
        .iter()
        .filter(|l| l.kind == "gemm")
        .map(|l| l.extra_roundtrips)
        .sum();
    assert_eq!(gemm_trips, 0, "fused epilogues must not round-trip");
    assert!(run.report.fused_elems > 0);
    // plan cache: repeated tiles across the batch hit
    assert_eq!(run.report.layers.len(), g.ops.len());
}

// ==================================================================
// Analytic vs cycle: end-to-end window error over the model zoo
// stays within the calibrated per-GEMM error bound
// ==================================================================

#[test]
fn zoo_analytic_tracks_cycle_within_per_gemm_bound() {
    let config = ConfigId::Zonl48Db;
    let cycle = GemmService::cycle();

    // Gather the zoo's distinct fused GEMMs with cycle ground truth.
    let mut jobs: Vec<(String, GemmJob)> = Vec::new();
    for name in zoo::models() {
        let g = zoo::build(name).unwrap();
        for op in &g.ops {
            if let NetOp::Gemm { x, w, epi, .. } = op {
                let (xt, wt) = (&g.tensors[*x], &g.tensors[*w]);
                jobs.push((
                    name.to_string(),
                    GemmJob::fused(
                        config,
                        xt.rows,
                        wt.cols,
                        xt.cols,
                        LayoutKind::Grouped,
                        *epi,
                    ),
                ));
            }
        }
    }
    let measured: Vec<_> = jobs
        .iter()
        .map(|(_, j)| cycle.run_job(j).unwrap())
        .collect();

    // Fit (alpha, beta, gamma, epsilon) on those samples.
    let samples: Vec<CalSample> =
        measured.iter().map(CalSample::from_result).collect();
    let cal = fit_calibration(&samples);
    let ana = GemmService::analytic_with(cal);

    // Per-GEMM error bound of the calibrated model on this set.
    let mut per_gemm_max = 0.0f64;
    let mut predicted: Vec<u64> = Vec::new();
    for ((_, j), r) in jobs.iter().zip(&measured) {
        let p = ana.run_job(j).unwrap();
        predicted.push(p.perf.window_cycles);
        let err = (p.perf.window_cycles as f64
            - r.perf.window_cycles as f64)
            .abs()
            / r.perf.window_cycles as f64;
        per_gemm_max = per_gemm_max.max(err);
    }
    assert!(
        per_gemm_max < 0.35,
        "calibrated per-GEMM window error too large: {per_gemm_max:.3}"
    );

    // End-to-end (per model): summed-window error can never exceed
    // the worst per-GEMM relative error — and must, in particular,
    // stay within the calibrated bound.
    let mut models_seen = 0;
    for name in zoo::models() {
        let mut cyc = 0.0f64;
        let mut pred = 0.0f64;
        for (i, (model, _)) in jobs.iter().enumerate() {
            if model == name {
                cyc += measured[i].perf.window_cycles as f64;
                pred += predicted[i] as f64;
            }
        }
        assert!(cyc > 0.0, "{name}: no GEMM windows measured");
        let e2e = (pred - cyc).abs() / cyc;
        assert!(
            e2e <= per_gemm_max + 1e-9,
            "{name}: end-to-end window error {e2e:.3} exceeds the \
             per-GEMM bound {per_gemm_max:.3}"
        );
        models_seen += 1;
    }
    assert_eq!(models_seen, zoo::models().len());
}
