//! NodeSim tests: the run-digest determinism harness (bit-identical
//! across host thread counts and FastPath settings), the
//! fault-injection conservation property over random shrinkable
//! `FaultPlan`s, router-policy properties (p2c outage avoidance,
//! session affinity, least-loaded vs round-robin tail latency), and
//! the nightly million-request digest run.
//!
//! The node engine itself is single-threaded virtual time; host
//! threads and `--fast-forward` only touch the per-model cost probes
//! that run through the real serve engine. The digest harness
//! therefore pins the whole stack end to end: if any backend tier,
//! probe, or event-ordering rule wobbles, 64 bits disagree.

use std::collections::{BTreeMap, BTreeSet};

use zerostall::backend::BackendKind;
use zerostall::coordinator::node::{
    run_digest, run_node, run_node_trace, FaultEvent, FaultPlan,
    NodeConfig, RouterPolicy, ShedReason,
};
use zerostall::coordinator::serve::{
    solo_latency, ArrivalTrace, Policy, ServeConfig, ServeRequest,
};
use zerostall::kernels::GemmService;
use zerostall::util::prop::{check, Config, Shrink};

fn serve_cfg(models: &[&str], clusters: usize) -> ServeConfig {
    let mut c = ServeConfig::new(
        models.iter().map(|s| s.to_string()).collect(),
    );
    c.clusters = clusters;
    c.slo = Some(u64::MAX);
    c.seed = 2026;
    c
}

/// Offered rate (req/Mcycle) that loads `fabrics` fabrics to `rho`
/// given a mean per-request service cost — probed at runtime so the
/// tests do not hard-code any backend's absolute cycle counts.
fn rate_for_load(rho: f64, fabrics: usize, mean_cost: u64) -> f64 {
    rho * fabrics as f64 * 1.0e6 / mean_cost as f64
}

fn mean_cost(svc: &GemmService, cfg: &ServeConfig) -> u64 {
    let costs: Vec<u64> = (0..cfg.models.len())
        .map(|mi| {
            solo_latency(svc, cfg, mi, Policy::Continuous).unwrap()
        })
        .collect();
    (costs.iter().sum::<u64>() / costs.len() as u64).max(1)
}

// =================================================================
// Checksum determinism harness: the acceptance scenario — 4 fabrics
// x 4 clusters, 10^5 requests, a mid-trace fabric failure — must
// produce a bit-identical run (and run digest) across 1/2/8 host
// threads, with zero lost requests and a stable p99.
// =================================================================

#[test]
fn node_digest_bit_identical_across_threads_100k() {
    let requests = 100_000usize;
    let svc = GemmService::analytic();
    let mut base = serve_cfg(&["ffn", "qkv"], 4);
    base.requests = requests;
    let cost = mean_cost(&svc, &base);
    base.rate_per_mcycle = rate_for_load(0.6, 4, cost);
    base.burst = 0.2;
    // Mid-trace failure: fabric 1 dies a third of the way through
    // the arrival span and comes back at two thirds.
    let span =
        requests as f64 * 1.0e6 / base.rate_per_mcycle;
    let down_at = (span / 3.0) as u64;
    let restore = (2.0 * span / 3.0) as u64;

    let mut runs = Vec::new();
    for threads in [1usize, 2, 8] {
        let mut scfg = base.clone();
        scfg.threads = threads;
        let mut cfg = NodeConfig::new(scfg, 4);
        cfg.router = RouterPolicy::PowerOfTwo;
        cfg.faults = FaultPlan {
            events: vec![FaultEvent {
                at: down_at,
                fabric: 1,
                restore: Some(restore),
            }],
        };
        let svc = GemmService::analytic();
        runs.push(run_node(&svc, &cfg).unwrap());
    }
    let r0 = &runs[0].report;
    // Zero lost requests: the fault window has a restore and three
    // fabrics stay up, so nothing is shed and everything completes.
    assert_eq!(r0.completed, requests, "lost requests");
    assert_eq!(r0.shed_total(), 0);
    assert!(r0.per_fabric[1].downtime > 0, "fault never applied");
    // Retry ledger: every requeue lands on exactly one request.
    let retries_seen: u64 = runs[0]
        .rows
        .iter()
        .map(|r| r.retries as u64)
        .chain(runs[0].sheds.iter().map(|s| s.retries as u64))
        .sum();
    assert_eq!(retries_seen, r0.retries_total);
    // Stable p99: finite and sane for a rho=0.6 node (generous
    // bound — the point is "not runaway", not a perf pin).
    assert!(r0.p99() > 0);
    assert!(
        r0.p99() < 50 * r0.model_costs.iter().max().unwrap(),
        "p99 {} looks like an unstable queue",
        r0.p99()
    );
    for run in &runs[1..] {
        assert_eq!(
            runs[0], *run,
            "node run differs across host thread counts"
        );
        assert_eq!(runs[0].report.digest, run.report.digest);
    }
    // The digest is recomputable from the public outcome streams.
    assert_eq!(
        run_digest(&runs[0].rows, &runs[0].sheds),
        runs[0].report.digest
    );
}

#[test]
fn node_digest_invariant_to_fast_forward_and_threads_cycle() {
    // The cycle backend actually simulates the cost probes, so keep
    // the trace at 2 x 10^4; FastPath bit-exactness (DESIGN.md S6)
    // must carry through the probes into an identical node digest.
    let requests = 20_000usize;
    let mut base = serve_cfg(&["ffn"], 2);
    base.requests = requests;
    base.rate_per_mcycle = 30.0;
    base.burst = 0.1;
    let mut runs = Vec::new();
    for (threads, ff) in [(2usize, true), (1, true), (2, false)] {
        let mut scfg = base.clone();
        scfg.threads = threads;
        let mut cfg = NodeConfig::new(scfg, 4);
        cfg.router = RouterPolicy::LeastLoaded;
        cfg.faults =
            FaultPlan::parse("t=100000000,fabric=0,restore=200000000")
                .unwrap();
        let svc = GemmService::of_kind_ff(BackendKind::Cycle, ff);
        runs.push(run_node(&svc, &cfg).unwrap());
    }
    // Backend name differs per service only in kind, not FastPath,
    // so whole-run equality is well-defined across all three.
    assert_eq!(
        runs[0], runs[1],
        "node run differs across thread counts on the cycle backend"
    );
    assert_eq!(
        runs[0], runs[2],
        "node run differs across --fast-forward on|off"
    );
    assert_eq!(runs[0].report.completed, requests);
}

// =================================================================
// Nightly scale: 10^6 requests behind the PROP_CASES gate (the
// nightly property job sets it; plain `cargo test` skips).
// =================================================================

#[test]
fn node_digest_million_requests_nightly() {
    if std::env::var("PROP_CASES").is_err() {
        eprintln!(
            "skipping 10^6-request digest run (set PROP_CASES to \
             enable; the nightly property job does)"
        );
        return;
    }
    let requests = 1_000_000usize;
    let svc = GemmService::analytic();
    let mut base = serve_cfg(&["ffn", "qkv"], 4);
    base.requests = requests;
    let cost = mean_cost(&svc, &base);
    base.rate_per_mcycle = rate_for_load(0.7, 4, cost);
    base.burst = 0.3;
    let span = requests as f64 * 1.0e6 / base.rate_per_mcycle;
    let mut runs = Vec::new();
    for threads in [2usize, 8] {
        let mut scfg = base.clone();
        scfg.threads = threads;
        let mut cfg = NodeConfig::new(scfg, 4);
        cfg.router = RouterPolicy::PowerOfTwo;
        cfg.faults = FaultPlan {
            events: vec![
                FaultEvent {
                    at: (span / 4.0) as u64,
                    fabric: 2,
                    restore: Some((span / 2.0) as u64),
                },
                FaultEvent {
                    at: (span / 2.0) as u64,
                    fabric: 0,
                    restore: Some((3.0 * span / 4.0) as u64),
                },
            ],
        };
        let svc = GemmService::analytic();
        runs.push(run_node(&svc, &cfg).unwrap());
    }
    assert_eq!(runs[0], runs[1], "10^6-request node run wobbled");
    let r = &runs[0].report;
    assert_eq!(r.requests, requests);
    assert_eq!(r.completed + r.shed_total(), requests);
}

// =================================================================
// Fault-injection conservation: over random fault plans, routers,
// retry budgets, and traces, no request is ever lost or
// double-completed — every arrival shows up exactly once, as a
// completion or a shed.
// =================================================================

#[derive(Clone, Debug)]
struct FaultScenario {
    trace: ArrivalTrace,
    plan: FaultPlan,
    fabrics: usize,
    router: usize,
    max_retries: u32,
    tight_admission: bool,
}

impl Shrink for FaultScenario {
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<FaultScenario> = self
            .plan
            .shrinks()
            .into_iter()
            .map(|plan| FaultScenario { plan, ..self.clone() })
            .collect();
        out.extend(self.trace.shrinks().into_iter().map(|trace| {
            FaultScenario { trace, ..self.clone() }
        }));
        if self.tight_admission {
            out.push(FaultScenario {
                tight_admission: false,
                ..self.clone()
            });
        }
        out
    }
}

#[test]
fn prop_fault_plans_conserve_requests() {
    let base = Config::default();
    check(
        &Config { cases: base.cases, seed: base.seed ^ 0x0DE5 },
        |rng| {
            let n = rng.range(4, 20);
            let mut t = 0u64;
            let requests = (0..n)
                .map(|id| {
                    t += rng.below(400_000);
                    ServeRequest {
                        id,
                        model: rng.range(0, 1),
                        arrival: t,
                        seed: rng.next_u64(),
                    }
                })
                .collect();
            let fabrics = rng.range(1, 4);
            let n_faults = rng.range(0, 3);
            let events = (0..n_faults)
                .map(|_| {
                    let at = rng.below(3_000_000);
                    let restore = if rng.bool() {
                        Some(at + 1 + rng.below(3_000_000))
                    } else {
                        None
                    };
                    FaultEvent {
                        at,
                        fabric: rng.range(0, fabrics - 1),
                        restore,
                    }
                })
                .collect();
            FaultScenario {
                trace: ArrivalTrace { requests },
                plan: FaultPlan { events },
                fabrics,
                router: rng.range(0, 3),
                max_retries: rng.range(0, 3) as u32,
                tight_admission: rng.bool(),
            }
        },
        |s| {
            let mut scfg = serve_cfg(&["ffn", "mlp"], 1);
            if s.tight_admission {
                // An SLO of 1 cycle with admission on sheds almost
                // everything — the conservation ledger must still
                // balance exactly.
                scfg.slo = Some(1);
            }
            let mut cfg = NodeConfig::new(scfg, s.fabrics.max(1));
            cfg.faults = s.plan.clone();
            cfg.max_retries = s.max_retries;
            cfg.router = match s.router % 4 {
                0 => RouterPolicy::RoundRobin,
                1 => RouterPolicy::LeastLoaded,
                2 => RouterPolicy::PowerOfTwo,
                _ => RouterPolicy::Affinity,
            };
            if s.tight_admission {
                cfg.admit_factor = Some(1.0);
            }
            let svc = GemmService::analytic();
            let run = run_node_trace(&svc, &cfg, &s.trace)
                .map_err(|e| e.to_string())?;
            let n = s.trace.requests.len();
            if run.rows.len() + run.sheds.len() != n {
                return Err(format!(
                    "{} arrivals != {} completions + {} sheds",
                    n,
                    run.rows.len(),
                    run.sheds.len()
                ));
            }
            // Exactly-once: the id sets partition the arrivals.
            let mut seen = BTreeSet::new();
            for id in run
                .rows
                .iter()
                .map(|r| r.id)
                .chain(run.sheds.iter().map(|sh| sh.id))
            {
                if !seen.insert(id) {
                    return Err(format!("request {id} seen twice"));
                }
            }
            let expect: BTreeSet<usize> =
                s.trace.requests.iter().map(|r| r.id).collect();
            if seen != expect {
                return Err("id sets do not partition".into());
            }
            for row in &run.rows {
                if row.completion <= row.arrival {
                    return Err(format!(
                        "req {} completed at {} <= arrival {}",
                        row.id, row.completion, row.arrival
                    ));
                }
                if row.retries > cfg.max_retries {
                    return Err(format!(
                        "req {} completed with {} retries > budget",
                        row.id, row.retries
                    ));
                }
            }
            for sh in &run.sheds {
                if sh.reason == ShedReason::RetryBudget
                    && sh.retries <= cfg.max_retries
                {
                    return Err(format!(
                        "req {} shed on retry budget at {} retries",
                        sh.id, sh.retries
                    ));
                }
            }
            Ok(())
        },
    );
}

// =================================================================
// Router-policy properties.
// =================================================================

/// p2c never routes to a down fabric: during an outage window no
/// request is dispatched on the dead fabric, and with no restore the
/// fabric never serves again.
#[test]
fn p2c_never_dispatches_into_an_outage() {
    let svc = GemmService::analytic();
    let mut base = serve_cfg(&["ffn"], 2);
    base.requests = 300;
    let cost = mean_cost(&svc, &base);
    base.rate_per_mcycle = rate_for_load(0.7, 3, cost);
    let span = base.requests as f64 * 1.0e6 / base.rate_per_mcycle;
    let down_at = (span / 3.0) as u64;
    let restore = (2.0 * span / 3.0) as u64;

    for restore_opt in [None, Some(restore)] {
        let mut cfg = NodeConfig::new(base.clone(), 3);
        cfg.router = RouterPolicy::PowerOfTwo;
        cfg.faults = FaultPlan {
            events: vec![FaultEvent {
                at: down_at,
                fabric: 0,
                restore: restore_opt,
            }],
        };
        let svc = GemmService::analytic();
        let run = run_node(&svc, &cfg).unwrap();
        let mut pre_fault_on_f0 = 0;
        for row in &run.rows {
            if row.fabric != 0 {
                continue;
            }
            // A completion on the dead fabric either fully predates
            // the outage or was dispatched at/after the restore; a
            // dispatch inside the window is impossible.
            let legal = row.completion < down_at
                || restore_opt
                    .is_some_and(|r| row.dispatched >= r);
            assert!(
                legal,
                "request {} ran on fabric 0 inside the outage \
                 (dispatched {}, completed {})",
                row.id, row.dispatched, row.completion
            );
            if row.completion < down_at {
                pre_fault_on_f0 += 1;
            }
        }
        assert!(
            pre_fault_on_f0 > 0,
            "p2c never used fabric 0 before the fault — scenario \
             too weak to test anything"
        );
        let r = &run.report;
        assert_eq!(r.completed + r.shed_total(), r.requests);
    }
}

/// Affinity keeps a session on one fabric unless that fabric dies;
/// after a death the session remaps exactly once.
#[test]
fn affinity_pins_sessions_until_their_fabric_dies() {
    let svc = GemmService::analytic();
    let mut base = serve_cfg(&["ffn"], 2);
    base.requests = 200;
    let cost = mean_cost(&svc, &base);
    base.rate_per_mcycle = rate_for_load(0.6, 3, cost);
    let span = base.requests as f64 * 1.0e6 / base.rate_per_mcycle;
    let down_at = (span / 3.0) as u64;

    // No faults: every session lives on exactly one fabric.
    let mut cfg = NodeConfig::new(base.clone(), 3);
    cfg.router = RouterPolicy::Affinity;
    cfg.sessions = 8;
    let run = run_node(&svc, &cfg).unwrap();
    assert_eq!(run.report.completed, 200);
    let mut by_session: BTreeMap<u64, BTreeSet<usize>> =
        BTreeMap::new();
    for row in &run.rows {
        by_session.entry(row.session).or_default().insert(row.fabric);
    }
    for (session, fabrics) in &by_session {
        assert_eq!(
            fabrics.len(),
            1,
            "session {session} spread over fabrics {fabrics:?} \
             with no faults"
        );
    }

    // Fabric 0 dies for good: sessions pinned there move exactly
    // once, everyone else stays put.
    let mut cfg = NodeConfig::new(base, 3);
    cfg.router = RouterPolicy::Affinity;
    cfg.sessions = 8;
    cfg.faults = FaultPlan {
        events: vec![FaultEvent {
            at: down_at,
            fabric: 0,
            restore: None,
        }],
    };
    let svc = GemmService::analytic();
    let run = run_node(&svc, &cfg).unwrap();
    let mut by_session: BTreeMap<u64, Vec<(usize, u64)>> =
        BTreeMap::new();
    for row in &run.rows {
        by_session
            .entry(row.session)
            .or_default()
            .push((row.fabric, row.dispatched));
    }
    for (session, rows) in &by_session {
        let fabrics: BTreeSet<usize> =
            rows.iter().map(|&(f, _)| f).collect();
        assert!(
            fabrics.len() <= 2,
            "session {session} used fabrics {fabrics:?}"
        );
        if fabrics.len() == 2 {
            assert!(
                fabrics.contains(&0),
                "session {session} moved between live fabrics \
                 {fabrics:?}"
            );
            for &(f, dispatched) in rows {
                if f != 0 {
                    assert!(
                        dispatched >= down_at,
                        "session {session} left fabric 0 before it \
                         died"
                    );
                }
            }
        }
    }
    let r = &run.report;
    assert_eq!(r.completed + r.shed_total(), r.requests);
}

/// Least-loaded beats round-robin p99 on a skewed mix (acceptance
/// bound in the PR 4 style: > 1.3x). The trace is adversarial for a
/// load-oblivious router and fully deterministic: heavy/light pairs
/// arrive together, spaced at the balanced service rate, so rr piles
/// every heavy request onto one fabric (its backlog grows linearly)
/// while ll keeps both backlogs bounded.
#[test]
fn least_loaded_beats_round_robin_p99_on_skewed_mix() {
    let svc = GemmService::analytic();
    let base = serve_cfg(&["llm", "mlp"], 2);
    let c0 =
        solo_latency(&svc, &base, 0, Policy::Continuous).unwrap();
    let c1 =
        solo_latency(&svc, &base, 1, Policy::Continuous).unwrap();
    let (heavy, light) = if c0 >= c1 { (0, 1) } else { (1, 0) };
    let (ch, cl) = (c0.max(c1), c0.min(c1));
    assert!(
        ch > cl,
        "zoo models llm/mlp cost the same ({ch}); the skewed-mix \
         scenario needs asymmetric service costs"
    );
    let pairs = 200usize;
    let gap = (ch + cl) / 2;
    let requests: Vec<ServeRequest> = (0..pairs)
        .flat_map(|i| {
            let t = i as u64 * gap;
            [
                ServeRequest {
                    id: 2 * i,
                    model: heavy,
                    arrival: t,
                    seed: 0xA5A5 ^ i as u64,
                },
                ServeRequest {
                    id: 2 * i + 1,
                    model: light,
                    arrival: t,
                    seed: 0x5A5A ^ i as u64,
                },
            ]
        })
        .collect();
    let trace = ArrivalTrace { requests };

    let mut p99 = BTreeMap::new();
    for router in
        [RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded]
    {
        let mut cfg = NodeConfig::new(base.clone(), 2);
        cfg.router = router;
        let svc = GemmService::analytic();
        let run = run_node_trace(&svc, &cfg, &trace).unwrap();
        assert_eq!(run.report.completed, 2 * pairs);
        assert_eq!(run.report.shed_total(), 0);
        p99.insert(router.name(), run.report.p99());
    }
    let (rr, ll) = (p99["rr"] as f64, p99["ll"] as f64);
    assert!(
        rr > 1.3 * ll,
        "least-loaded p99 {ll} not 1.3x better than round-robin \
         p99 {rr} on the skewed mix"
    );
}
