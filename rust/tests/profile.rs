//! StallScope integration tests — the acceptance criteria of the
//! profiling subsystem:
//!
//! * conservation: `useful + Σstalls == cycles` per core, bit-exact,
//!   on every zoo GEMM shape;
//! * decomposition: StallScope utilization equals the existing
//!   `ClusterPerf` utilization bit for bit (Useful counts exactly the
//!   `fpu_ops` events over the same window);
//! * the paper's zero-conflict claim: the optimized Dobu config
//!   attributes ~0 cycles to `BankConflict` while the 32-bank
//!   baseline shows a nonzero bank-conflict share on contended
//!   (multi-pass, DMA-overlapped) shapes;
//! * the analytic backend's *predicted* breakdown tracks the measured
//!   one (differential, generous first-order bounds).

use zerostall::cluster::ConfigId;
use zerostall::coordinator::workload::{zoo, Problem};
use zerostall::kernels::{GemmJob, GemmService, LayoutKind};
use zerostall::profile::{StallClass, N_CLASSES};

/// Every distinct GEMM shape across the whole zoo.
fn zoo_problems() -> Vec<Problem> {
    let mut out: Vec<Problem> = Vec::new();
    for name in zoo::models() {
        let g = zoo::build(name).unwrap();
        for (_, p) in g.problems() {
            if !out.contains(&p) {
                out.push(p);
            }
        }
    }
    out
}

#[test]
fn conservation_and_utilization_equality_on_every_zoo_shape() {
    let svc = GemmService::cycle();
    for p in zoo_problems() {
        let job = GemmJob::for_problem(
            ConfigId::Zonl48Db,
            p.m,
            p.n,
            p.k,
            LayoutKind::Grouped,
        );
        let r = svc.run_job(&job).unwrap();
        let s = &r.perf.stalls;
        s.check_conservation()
            .unwrap_or_else(|e| panic!("{p}: {e}"));
        // Useful == fpu_ops (same events), so the decomposition's
        // utilization is bit-identical to the headline metric.
        assert_eq!(
            s.useful_total(),
            r.perf.fpu_ops_total,
            "{p}: Useful must count exactly the fpu_ops events"
        );
        assert_eq!(s.window_cycles, r.perf.window_cycles);
        assert_eq!(
            s.utilization().to_bits(),
            r.perf.utilization.to_bits(),
            "{p}: StallScope utilization must equal ClusterPerf's"
        );
        // Every class total is covered by the attributed cycles.
        let totals = s.totals();
        assert_eq!(
            totals.iter().sum::<u64>(),
            s.cycles_total(),
            "{p}: totals partition attributed cycles"
        );
    }
}

#[test]
fn dobu_attributes_zero_conflicts_baseline_does_not() {
    // Contended shapes: multi-pass with DMA/compute overlap, where
    // the 32-bank baseline's grouped layout cannot give every buffer
    // a private superbank.
    // 96^3 is the shape `zero_dma_conflicts_on_dobu_configs` pins:
    // base32fc measurably suffers DMA-mux captures there, Dobu none.
    let shapes = [
        Problem { m: 96, n: 96, k: 96 },
        Problem { m: 96, n: 64, k: 80 },
        Problem { m: 64, n: 128, k: 64 },
    ];
    let svc = GemmService::cycle();
    let bc = StallClass::BankConflict as usize;
    let mut base_conflict_cycles = 0u64;
    for p in shapes {
        let run = |id: ConfigId| {
            svc.run_job(&GemmJob::for_problem(
                id,
                p.m,
                p.n,
                p.k,
                LayoutKind::Grouped,
            ))
            .unwrap()
        };
        let dobu = run(ConfigId::Zonl48Db);
        let base = run(ConfigId::Base32Fc);
        let dobu_share = dobu.perf.stalls.shares()[bc];
        let base_share = base.perf.stalls.shares()[bc];
        assert!(
            dobu_share < 0.02,
            "{p}: Dobu bank-conflict share {dobu_share:.4} — the \
             zero-conflict claim"
        );
        assert!(
            base_share >= dobu_share,
            "{p}: baseline share {base_share:.4} < dobu {dobu_share:.4}"
        );
        base_conflict_cycles += base.perf.stalls.totals()[bc];
        // Sanity: when the machine reported retried requests, some
        // cycles must be attributed to BankConflict.
        if base.perf.conflicts_total() > 100 {
            assert!(
                base.perf.stalls.totals()[bc] > 0,
                "{p}: {} retried requests but no BankConflict cycles",
                base.perf.conflicts_total()
            );
        }
    }
    assert!(
        base_conflict_cycles > 0,
        "the 32-bank baseline must show bank-conflict cycles on at \
         least one contended shape"
    );
}

#[test]
fn sharded_profiles_conserve_and_merge() {
    use zerostall::fabric::FabricConfig;
    let svc = GemmService::cycle();
    let job = GemmJob::for_problem(
        ConfigId::Zonl48Db,
        64,
        64,
        32,
        LayoutKind::Grouped,
    );
    let fr = svc.run_sharded_job(&job, &FabricConfig::new(4)).unwrap();
    assert_eq!(fr.clusters(), 4);
    for s in &fr.shards {
        s.perf.stalls.check_conservation().unwrap();
    }
    let merged = fr.stall_profile();
    merged.check_conservation().unwrap();
    assert_eq!(merged.n_compute, 4 * 8);
    assert_eq!(merged.dm_cores().len(), 4);
    // Merged useful must equal the fabric's total FPU ops.
    assert_eq!(merged.useful_total(), fr.fpu_ops_total());
}

#[test]
fn noc_gating_attributed_on_starved_fabrics() {
    use zerostall::fabric::FabricConfig;
    use zerostall::fabric::NocConfig;
    // 8 DMA-heavy shards behind a single-beat NoC: gated cycles must
    // surface in the NocGated bucket (and in the DMA engine counter).
    let svc = GemmService::cycle();
    let job = GemmJob::for_problem(
        ConfigId::Zonl48Db,
        128,
        128,
        8,
        LayoutKind::Grouped,
    );
    let fabric = FabricConfig {
        clusters: 8,
        noc: NocConfig { links: 1, beats_per_link: 1 },
    };
    let fr = svc.run_sharded_job(&job, &fabric).unwrap();
    let ng = StallClass::NocGated as usize;
    let merged = fr.stall_profile();
    merged.check_conservation().unwrap();
    assert!(
        merged.totals()[ng] > 0
            || merged.dm_cores().iter().any(|c| c.counts[ng] > 0),
        "a saturated NoC must leave NocGated evidence"
    );
    let gated: u64 = fr
        .shards
        .iter()
        .map(|s| s.perf.dma_noc_gated_cycles)
        .sum();
    assert!(gated > 0, "DMA engines must record NoC-gated cycles");
}

#[test]
fn analytic_breakdown_tracks_cycle_breakdown() {
    // Differential: the predicted decomposition agrees with the
    // measured one on the broad strokes — Useful share within the
    // first-order window bound, bank conflicts ~0 where the machine
    // shows ~0, and the combined overhead share in the same regime.
    let cycle = GemmService::cycle();
    let analytic = GemmService::analytic();
    for (id, p) in [
        (ConfigId::Zonl48Db, Problem { m: 64, n: 64, k: 64 }),
        (ConfigId::Zonl48Db, Problem { m: 32, n: 32, k: 32 }),
        (ConfigId::Base32Fc, Problem { m: 32, n: 32, k: 32 }),
    ] {
        let job = GemmJob::for_problem(
            id,
            p.m,
            p.n,
            p.k,
            LayoutKind::Grouped,
        );
        let c = cycle.run_job(&job).unwrap();
        let a = analytic.run_job(&job).unwrap();
        c.perf.stalls.check_conservation().unwrap();
        a.perf.stalls.check_conservation().unwrap();
        let cs = c.perf.stalls.shares();
        let as_ = a.perf.stalls.shares();
        let useful = StallClass::Useful as usize;
        assert!(
            (cs[useful] - as_[useful]).abs() < 0.30,
            "{} {p}: useful share measured {:.3} vs predicted {:.3}",
            id.name(),
            cs[useful],
            as_[useful]
        );
        // Both sides sum their shares to 1 (full attribution).
        let sum_c: f64 = cs.iter().sum();
        let sum_a: f64 = as_.iter().sum();
        assert!((sum_c - 1.0).abs() < 1e-9, "{sum_c}");
        assert!((sum_a - 1.0).abs() < 1e-9, "{sum_a}");
    }
}

#[test]
fn run_profile_llm_end_to_end() {
    use zerostall::coordinator::profile::{run_profile, ProfileOpts};
    let opts = ProfileOpts::new("mlp");
    let (rep, _) = run_profile(&opts).unwrap();
    assert_eq!(rep.layers.len(), 4, "mlp has 4 GEMM layers");
    assert_eq!(rep.skipped_adds, 0);
    rep.merged.check_conservation().unwrap();
    assert_eq!(rep.merged.totals().len(), N_CLASSES);
    // Timeline: total equals the sum of layer cycles.
    let sum: u64 = rep.layers.iter().map(|l| l.cycles).sum();
    assert_eq!(rep.total_cycles, sum);
    // Rooflines carry the per-layer ops (MACs + fused epilogues).
    for l in &rep.layers {
        assert_eq!(
            l.roofline.ops,
            l.stalls.useful_total(),
            "{}: roofline ops == useful cycles == fpu ops",
            l.name
        );
    }
}
