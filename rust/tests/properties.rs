//! Property-based tests over the simulator's core invariants,
//! using the built-in mini framework (`util::prop` — proptest is not
//! available offline; see DESIGN.md).

use zerostall::cluster::ConfigId;
use zerostall::core::sequencer::{
    oracle_expand, run_sequencer, NestItem, SeqConfig, Sequencer,
};
use zerostall::isa::{
    decode::decode, disasm::disasm, encode::encode, Instr, SsrField,
};
use zerostall::kernels::{
    choose_tiling, plan_buffers, LayoutKind, Tiling,
};
use zerostall::mem::{
    DmaBeat, Interconnect, PortRequest, Tcdm, Topology,
    BANKS_PER_SUPERBANK, TCDM_BASE,
};
use zerostall::ssr::{oracle_addresses, Streamer};
use zerostall::util::prop::{check, Config, Shrink};
use zerostall::util::rng::Rng;

fn cfg(cases: usize, seed: u64) -> Config {
    Config { cases, seed }
}

// =================================================================
// FREP sequencer vs software loop-nest oracle (the paper's §III-A
// correctness claim, incl. loops sharing start/end instructions).
// =================================================================

/// A generated nest program (shrinkable).
#[derive(Clone, Debug)]
struct NestProg(Vec<(u8, u32, u32)>); // (kind, n_inst, n_iter) kind0=op

impl Shrink for NestProg {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.0.len() > 1 {
            out.push(NestProg(self.0[..self.0.len() / 2].to_vec()));
            let mut v = self.0.clone();
            v.pop();
            out.push(NestProg(v));
        }
        out
    }
}

fn gen_nest(rng: &mut Rng, max_depth: usize) -> Vec<NestItem> {
    // Build a random proper nest bottom-up: generate a body, count its
    // RB-resident ops, then (maybe) wrap it in a loop — so every
    // declared n_inst matches the instructions that actually follow.
    // Loops may share start and/or end instructions with their parent.
    fn segment(
        rng: &mut Rng,
        depth: usize,
        max_depth: usize,
        next_id: &mut u8,
    ) -> (Vec<NestItem>, u32) {
        let mut items = Vec::new();
        let mut ops = 0u32;
        let pieces = rng.range(1, 3);
        for _ in 0..pieces {
            if depth < max_depth && rng.below(2) == 0 {
                let (body, body_ops) =
                    segment(rng, depth + 1, max_depth, next_id);
                if body_ops > 0 {
                    items.push(NestItem::Loop {
                        n_inst: body_ops,
                        n_iter: rng.range(1, 4) as u32,
                    });
                    items.extend(body);
                    ops += body_ops;
                }
            } else {
                for _ in 0..rng.range(1, 3) {
                    items.push(NestItem::Op(*next_id));
                    *next_id = next_id.wrapping_add(1);
                    ops += 1;
                }
            }
        }
        (items, ops)
    }
    let mut out = Vec::new();
    let mut id = 1u8;
    for _ in 0..rng.range(1, 3) {
        let (seg, seg_ops) = segment(rng, 1, max_depth, &mut id);
        if seg_ops > 0 && rng.bool() {
            out.push(NestItem::Loop {
                n_inst: seg_ops,
                n_iter: rng.range(1, 5) as u32,
            });
        }
        out.extend(seg);
    }
    out
}

#[test]
fn prop_sequencer_matches_oracle_zonl() {
    check(
        &cfg(200, 0xA11CE),
        |rng| {
            let items = gen_nest(rng, 3);
            // encode to the shrinkable carrier
            NestProg(
                items
                    .iter()
                    .map(|i| match i {
                        NestItem::Op(id) => (0u8, *id as u32, 0),
                        NestItem::Loop { n_inst, n_iter } => {
                            (1u8, *n_inst, *n_iter)
                        }
                    })
                    .collect(),
            )
        },
        |prog| {
            let items: Vec<NestItem> = prog
                .0
                .iter()
                .map(|&(k, a, b)| {
                    if k == 0 {
                        NestItem::Op(a as u8)
                    } else {
                        NestItem::Loop { n_inst: a, n_iter: b }
                    }
                })
                .collect();
            // Validate well-formedness (shrinking may truncate bodies:
            // every loop must be followed by >= n_inst ops in scope).
            let total_ops = items
                .iter()
                .filter(|i| matches!(i, NestItem::Op(_)))
                .count() as u32;
            let mut pos = 0u32;
            for it in &items {
                match it {
                    NestItem::Op(_) => pos += 1,
                    NestItem::Loop { n_inst, .. } => {
                        if pos + n_inst > total_ops {
                            return Ok(()); // malformed after shrink
                        }
                    }
                }
            }
            let want = oracle_expand(&items);
            if want.len() > 50_000 {
                return Ok(()); // keep runtime bounded
            }
            let mut seq = Sequencer::new(SeqConfig {
                rb_depth: 64,
                max_nest_depth: 4,
                block_offload_during_loop: false,
            });
            let (got, cycles) = run_sequencer(&mut seq, &items);
            if got != want {
                return Err(format!(
                    "trace mismatch: got {} ops want {}",
                    got.len(),
                    want.len()
                ));
            }
            // Zero-overhead claim: one instruction per cycle modulo
            // the frontend feed (items.len() is an upper bound on the
            // non-overlapped feed cycles).
            let budget = want.len() as u64 + items.len() as u64 + 4;
            if cycles > budget {
                return Err(format!(
                    "{cycles} cycles for {} ops (budget {budget})",
                    want.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sequencer_baseline_sequential_loops() {
    // Baseline (depth-1, blocking) must still execute any flat
    // sequence of non-nested loops correctly.
    check(
        &cfg(100, 0xB0B),
        |rng| {
            let mut v = Vec::new();
            for _ in 0..rng.range(1, 4) {
                v.push((1u8, rng.range(1, 5) as u32, rng.range(1, 6) as u32));
                for i in 0..v.last().unwrap().1 {
                    v.push((0u8, i + 1, 0));
                }
            }
            NestProg(v)
        },
        |prog| {
            let mut items = Vec::new();
            let mut expect_ops = 0usize;
            let mut it = prog.0.iter().peekable();
            while let Some(&(k, a, b)) = it.next() {
                if k == 1 {
                    // collect exactly `a` following ops as the body
                    let mut body = Vec::new();
                    for _ in 0..a {
                        match it.next() {
                            Some(&(0, id, _)) => {
                                body.push(NestItem::Op(id as u8))
                            }
                            _ => return Ok(()), // malformed after shrink
                        }
                    }
                    items.push(NestItem::Loop { n_inst: a, n_iter: b });
                    expect_ops += a as usize * b as usize;
                    items.extend(body);
                } else {
                    items.push(NestItem::Op(a as u8));
                    expect_ops += 1;
                }
            }
            let want = oracle_expand(&items);
            if want.len() != expect_ops {
                return Ok(()); // malformed program after shrinking
            }
            let mut seq = Sequencer::new(SeqConfig::baseline());
            let (got, _) = run_sequencer(&mut seq, &items);
            if got != want {
                return Err("baseline trace mismatch".into());
            }
            Ok(())
        },
    );
}

// =================================================================
// SSR address generator vs affine oracle
// =================================================================

#[test]
fn prop_ssr_addrgen_matches_oracle() {
    check(
        &cfg(300, 0x55E),
        |rng| {
            let dims = rng.range(1, 4);
            let mut v = vec![dims as usize];
            for _ in 0..dims {
                v.push(rng.range(1, 6)); // bound
                v.push(rng.range(0, 5) * 8); // stride (bytes)
            }
            v
        },
        |spec| {
            let dims = spec[0].min(4).max(1);
            if spec.len() < 1 + 2 * dims {
                return Ok(());
            }
            let bounds: Vec<u32> = (0..dims)
                .map(|d| spec[1 + 2 * d].max(1) as u32)
                .collect();
            let strides: Vec<i32> =
                (0..dims).map(|d| spec[2 + 2 * d] as i32).collect();
            let base = 0x1000u32;
            let mut s = Streamer::new();
            for d in 0..dims {
                s.config(SsrField::Bound(d as u8), bounds[d] - 1);
                s.config(SsrField::Stride(d as u8), strides[d] as u32);
            }
            s.config(SsrField::ReadBase(dims as u8 - 1), base);
            let want = oracle_addresses(base, &bounds, &strides);
            let mut got = Vec::new();
            while let Some(addr) = s.read_request() {
                got.push(addr);
                s.read_granted(0.0);
                while s.can_pop() {
                    s.pop();
                }
                if got.len() > want.len() + 8 {
                    break;
                }
            }
            if got != want {
                return Err(format!(
                    "addr trace mismatch ({} vs {})",
                    got.len(),
                    want.len()
                ));
            }
            Ok(())
        },
    );
}

// =================================================================
// ISA encode/decode round-trip on randomized instructions
// =================================================================

#[test]
fn prop_isa_roundtrip() {
    check(
        &cfg(500, 0x15A),
        |rng| {
            vec![
                rng.range(0, 20),          // opcode selector
                rng.range(0, 31),          // rd
                rng.range(0, 31),          // rs1
                rng.range(0, 31),          // rs2
                rng.range(0, 4094) as usize, // imm-ish
            ]
        },
        |v| {
            if v.len() < 5 {
                return Ok(());
            }
            let (rd, rs1, rs2) =
                (v[1] as u8 & 31, v[2] as u8 & 31, v[3] as u8 & 31);
            let imm = (v[4] as i32 & 0xFFF) - 2048;
            let i = match v[0] % 17 {
                0 => Instr::Addi { rd, rs1, imm },
                1 => Instr::Add { rd, rs1, rs2 },
                2 => Instr::Sub { rd, rs1, rs2 },
                3 => Instr::Mul { rd, rs1, rs2 },
                4 => Instr::Bne { rs1, rs2, off: (imm / 2) * 2 },
                5 => Instr::Lw { rd, rs1, imm },
                6 => Instr::Sw { rs2, rs1, imm },
                7 => Instr::Fld { frd: rd, rs1, imm },
                8 => Instr::Fsd { frs2: rs2, rs1, imm },
                9 => Instr::FmaddD {
                    frd: rd,
                    frs1: rs1,
                    frs2: rs2,
                    frs3: rd,
                },
                10 => Instr::FmulD { frd: rd, frs1: rs1, frs2: rs2 },
                11 => Instr::Frep {
                    outer: imm & 1 == 0,
                    iters_reg: rs1,
                    n_inst: (imm & 0xFF) as u8,
                },
                12 => Instr::SsrCfgW {
                    value: rs1,
                    ssr: (rd & 3).min(2),
                    field: SsrField::Bound(rs2 & 3),
                },
                13 => Instr::Dmcpy { rd, rs1 },
                14 => Instr::Lui { rd, imm: imm << 12 },
                15 => Instr::Slli { rd, rs1, shamt: rs2 & 31 },
                _ => Instr::Csrrs { rd, csr: 0x7C0, rs1 },
            };
            let w = encode(&i);
            match decode(w) {
                Some(back) if back == i => Ok(()),
                Some(back) => {
                    Err(format!("{i:?} -> {w:#x} -> {back:?}"))
                }
                None => Err(format!("{i:?} -> {w:#x} -> None")),
            }
        },
    );
}

// =================================================================
// ISA round-trip, full variant coverage: every one of the 43 decoded
// IR variants, built from random (but canonical) field values, must
// survive encode -> decode -> encode with a bit-identical word and a
// stable disassembly. The one architectural alias — `addi x0,x0,0`
// decodes as `nop` — is asserted explicitly.
// =================================================================

const N_VARIANTS: u64 = 43;

/// Build variant `sel` from raw field entropy, canonicalized to the
/// encodable domain (immediate widths, even branch offsets, masked
/// U-type immediates, valid SSR field words).
fn build_instr(sel: u64, f: &[u64]) -> Instr {
    let g = |i: usize| f.get(i).copied().unwrap_or(0);
    let r = |i: usize| (g(i) % 32) as u8;
    let (rd, rs1, rs2) = (r(0), r(1), r(2));
    // 12-bit signed I/S immediate.
    let imm12 = ((g(3) % 4096) as i32) - 2048;
    // 13-bit signed, even branch offset.
    let boff = (((g(3) % 4096) as i32) - 2048) * 2;
    // 21-bit signed, even jump offset.
    let joff = (((g(3) % 1_048_576) as i32) - 524_288) * 2;
    // U-type: low 12 bits are zero by construction.
    let uimm = (((g(3) as u32) & 0xF_FFFF) << 12) as i32;
    let csr = (g(3) % 4096) as u16;
    match sel % N_VARIANTS {
        0 => Instr::Lui { rd, imm: uimm },
        1 => Instr::Auipc { rd, imm: uimm },
        2 => Instr::Addi { rd, rs1, imm: imm12 },
        3 => Instr::Slli { rd, rs1, shamt: rs2 },
        4 => Instr::Srli { rd, rs1, shamt: rs2 },
        5 => Instr::Andi { rd, rs1, imm: imm12 },
        6 => Instr::Add { rd, rs1, rs2 },
        7 => Instr::Sub { rd, rs1, rs2 },
        8 => Instr::Mul { rd, rs1, rs2 },
        9 => Instr::Beq { rs1, rs2, off: boff },
        10 => Instr::Bne { rs1, rs2, off: boff },
        11 => Instr::Blt { rs1, rs2, off: boff },
        12 => Instr::Bge { rs1, rs2, off: boff },
        13 => Instr::Jal { rd, off: joff },
        14 => Instr::Lw { rd, rs1, imm: imm12 },
        15 => Instr::Sw { rs2, rs1, imm: imm12 },
        16 => Instr::Csrrw { rd, csr, rs1 },
        17 => Instr::Csrrs { rd, csr, rs1 },
        18 => Instr::Csrrsi { csr, imm: rs2 },
        19 => Instr::Csrrci { csr, imm: rs2 },
        20 => Instr::Fld { frd: rd, rs1, imm: imm12 },
        21 => Instr::Fsd { frs2: rs2, rs1, imm: imm12 },
        22 => Instr::FmaddD {
            frd: rd,
            frs1: rs1,
            frs2: rs2,
            frs3: (g(3) % 32) as u8,
        },
        23 => Instr::FmulD { frd: rd, frs1: rs1, frs2: rs2 },
        24 => Instr::FaddD { frd: rd, frs1: rs1, frs2: rs2 },
        25 => Instr::FsubD { frd: rd, frs1: rs1, frs2: rs2 },
        26 => Instr::FmaxD { frd: rd, frs1: rs1, frs2: rs2 },
        27 => Instr::FsgnjD { frd: rd, frs1: rs1, frs2: rs2 },
        28 => Instr::FgeluD { frd: rd, frs1: rs1 },
        29 => Instr::FcvtDW { frd: rd, rs1 },
        30 => Instr::Frep {
            outer: g(3) & 1 == 0,
            iters_reg: rs1,
            n_inst: (g(3) % 256) as u8,
        },
        31 => {
            let field = match g(3) % 17 {
                0 => SsrField::Repeat,
                d @ 1..=4 => SsrField::Bound(d as u8 - 1),
                d @ 5..=8 => SsrField::Stride(d as u8 - 5),
                d @ 9..=12 => SsrField::ReadBase(d as u8 - 9),
                d => SsrField::WriteBase(d as u8 - 13),
            };
            Instr::SsrCfgW { value: rs1, ssr: (g(2) % 4) as u8, field }
        }
        32 => Instr::Dmsrc { rs1 },
        33 => Instr::Dmdst { rs1 },
        34 => Instr::Dmstr { rs1, rs2 },
        35 => Instr::Dmrep { rs1 },
        36 => Instr::Dmstr2 { rs1, rs2 },
        37 => Instr::Dmrep2 { rs1 },
        38 => Instr::Dmcpy { rd, rs1 },
        39 => Instr::Dmstat { rd },
        40 => Instr::Barrier,
        41 => Instr::Ecall,
        _ => Instr::Nop,
    }
}

#[test]
fn prop_isa_roundtrip_covers_every_variant() {
    // The alias pair, pinned deterministically (the random fields
    // reach the all-zero addi only rarely).
    assert_eq!(
        decode(encode(&Instr::Addi { rd: 0, rs1: 0, imm: 0 })),
        Some(Instr::Nop)
    );
    check(
        &cfg(300, 0xB17),
        |rng| {
            (0..4).map(|_| rng.next_u64()).collect::<Vec<u64>>()
        },
        |fields| {
            for sel in 0..N_VARIANTS {
                let i = build_instr(sel, fields);
                let w = encode(&i);
                let Some(back) = decode(w) else {
                    return Err(format!("{i:?} -> {w:#010x} -> None"));
                };
                // Word-level bit identity through the round trip.
                let w2 = encode(&back);
                if w2 != w {
                    return Err(format!(
                        "{i:?}: {w:#010x} re-encodes as {w2:#010x} \
                         via {back:?}"
                    ));
                }
                // IR identity, modulo the one architectural alias.
                let alias =
                    i == Instr::Addi { rd: 0, rs1: 0, imm: 0 };
                if alias {
                    if back != Instr::Nop {
                        return Err(format!(
                            "addi x0,x0,0 must decode as nop, got \
                             {back:?}"
                        ));
                    }
                } else if back != i {
                    return Err(format!(
                        "{i:?} -> {w:#010x} -> {back:?}"
                    ));
                }
                // Disassembly is stable across the round trip.
                let (d1, d2) = (disasm(&i), disasm(&back));
                if d1.is_empty() || (!alias && d1 != d2) {
                    return Err(format!(
                        "disasm drift for {i:?}: `{d1}` vs `{d2}`"
                    ));
                }
            }
            Ok(())
        },
    );
}

// =================================================================
// Interconnect: requests to distinct banks never conflict
// =================================================================

#[test]
fn prop_distinct_banks_no_conflicts() {
    check(
        &cfg(200, 0xD15C),
        |rng| {
            // distinct bank picks
            let n = rng.range(1, 24);
            let mut banks: Vec<usize> = (0..32).collect();
            // Fisher-Yates prefix shuffle
            for i in 0..n {
                let j = rng.range(i, 31);
                banks.swap(i, j);
            }
            banks[..n].to_vec()
        },
        |banks| {
            use zerostall::mem::{Interconnect, PortRequest};
            let mut tcdm =
                Tcdm::new(Topology::Fc { banks: 32 }, 128 * 1024);
            let mut x = Interconnect::new(32, 64);
            let reqs: Vec<PortRequest> = banks
                .iter()
                .enumerate()
                .map(|(i, &b)| PortRequest {
                    port: i as u16,
                    addr: TCDM_BASE + (b as u32) * 8,
                    write: false,
                    data: 0,
                })
                .collect();
            let mut grants = vec![false; reqs.len()];
            let mut data = vec![0u64; reqs.len()];
            x.arbitrate(&mut tcdm, &reqs, &mut grants, &mut data, None);
            if grants.iter().all(|&g| g) {
                Ok(())
            } else {
                Err("conflict among distinct banks".into())
            }
        },
    );
}

// =================================================================
// Dobu hyperbank-boundary addressing: bank_of / hyperbank_of /
// superbank_of_bank agree at the seam, and a maximal-width DMA beat
// ending exactly at the boundary never trips the crosses-superbank
// debug assert.
// =================================================================

/// A Dobu geometry (atomic for shrinking — the space is tiny).
#[derive(Clone, Debug)]
struct DobuSpec {
    banks_per_hyper: usize,
    words_per_bank: usize,
}

impl Shrink for DobuSpec {
    fn shrinks(&self) -> Vec<Self> {
        Vec::new()
    }
}

#[test]
fn prop_dobu_hyperbank_boundary_addressing() {
    check(
        &cfg(40, 0xD0B0),
        |rng| DobuSpec {
            banks_per_hyper: rng.range(1, 5) * 8,
            words_per_bank: rng.range(1, 8) * 64,
        },
        |spec| {
            let bph = spec.banks_per_hyper;
            let bytes = 2 * bph * spec.words_per_bank * 8;
            let mut t = Tcdm::new(
                Topology::Dobu { banks_per_hyper: bph },
                bytes,
            );
            let half = (bytes / 2) as u32;
            let last0 = TCDM_BASE + half - 8; // last word of hb 0
            let first1 = TCDM_BASE + half; // first word of hb 1
            if t.hyperbank_of(last0) != 0 {
                return Err("last word left hyperbank 0".into());
            }
            if t.hyperbank_of(first1) != 1 {
                return Err("first word not in hyperbank 1".into());
            }
            if t.bank_of(last0) != bph - 1 {
                return Err(format!(
                    "last word of hb0 in bank {} (want {})",
                    t.bank_of(last0),
                    bph - 1
                ));
            }
            if t.bank_of(first1) != bph {
                return Err(format!(
                    "first word of hb1 in bank {} (want {bph})",
                    t.bank_of(first1)
                ));
            }
            // Superbank view agrees: the seam separates the last
            // superbank of hb0 from the first of hb1.
            let sb_last = t.superbank_of_bank(t.bank_of(last0));
            let sb_first = t.superbank_of_bank(t.bank_of(first1));
            if sb_last != bph / BANKS_PER_SUPERBANK - 1
                || sb_first != bph / BANKS_PER_SUPERBANK
            {
                return Err(format!(
                    "superbanks straddle the seam: {sb_last} / \
                     {sb_first}"
                ));
            }
            // Maximal-width beats hugging the seam from both sides:
            // neither may trip the crosses-superbank debug assert
            // inside arbitrate (active in test builds).
            let mut x = Interconnect::new(2 * bph, 36);
            let reqs: Vec<PortRequest> = Vec::new();
            let mut grants: Vec<bool> = Vec::new();
            let mut data: Vec<u64> = Vec::new();
            for (addr, tag) in [
                (TCDM_BASE + half - 64, 7u64), // ends at the seam
                (first1, 9u64),                // starts at the seam
            ] {
                let beat = DmaBeat {
                    addr,
                    n_words: 8,
                    write: true,
                    data: [tag; 8],
                };
                let o = x.arbitrate(
                    &mut t,
                    &reqs,
                    &mut grants,
                    &mut data,
                    Some(&beat),
                );
                if !o.dma_granted {
                    return Err("uncontested beat denied".into());
                }
                for w in 0..8u32 {
                    if t.read_u64(addr + w * 8) != tag {
                        return Err(format!(
                            "beat word {w} lost at {addr:#x}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// =================================================================
// Grouped layout: every buffer stays within its superbank
// =================================================================

#[test]
fn prop_grouped_layout_confinement() {
    check(
        &cfg(150, 0x6E0),
        |rng| {
            vec![
                rng.range(1, 16) * 8, // m
                rng.range(1, 16) * 8, // n
                rng.range(1, 16) * 8, // k
                rng.range(0, 4),      // config index
            ]
        },
        |v| {
            if v.len() < 4 {
                return Ok(());
            }
            let (m, n, k) = (v[0].max(8), v[1].max(8), v[2].max(8));
            let id = ConfigId::all()[v[3] % 5];
            let c = id.cluster_config();
            let Some(t) = choose_tiling(m, n, k, c.tcdm_bytes) else {
                return Err(format!("no tiling for {m}x{n}x{k}"));
            };
            let map = plan_buffers(
                &t,
                c.topology,
                c.tcdm_bytes,
                LayoutKind::Grouped,
            );
            let tcdm = Tcdm::new(c.topology, c.tcdm_bytes);
            let tiles = [
                (map.a, t.mt * t.k),
                (map.b, t.k * t.nt),
                (map.c, t.mt * t.nt),
            ];
            for (bufs, words) in tiles {
                for d in bufs {
                    let sb0 =
                        tcdm.superbank_of_bank(tcdm.bank_of(d.base));
                    for i in (0..words).step_by(7) {
                        let addr = d.base
                            + (i / 8) as u32 * d.chunk_stride
                            + (i % 8) as u32 * 8;
                        if !tcdm.contains(addr) {
                            return Err(format!(
                                "OOB {addr:#x} ({m}x{n}x{k} {})",
                                id.name()
                            ));
                        }
                        let sb = tcdm
                            .superbank_of_bank(tcdm.bank_of(addr));
                        if sb != sb0 {
                            return Err(format!(
                                "escaped superbank ({m}x{n}x{k})"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

// =================================================================
// Tiling: solver output always legal
// =================================================================

#[test]
fn prop_tiling_legal() {
    check(
        &cfg(300, 0x717),
        |rng| {
            vec![
                rng.range(1, 16) * 8,
                rng.range(1, 16) * 8,
                rng.range(1, 16) * 8,
            ]
        },
        |v| {
            if v.len() < 3 {
                return Ok(());
            }
            let (m, n, k) = (v[0].max(8), v[1].max(8), v[2].max(8));
            for bytes in [96 * 1024, 128 * 1024] {
                let Some(t) = choose_tiling(m, n, k, bytes) else {
                    return Err(format!("no tiling {m}x{n}x{k}"));
                };
                let legal = m % t.mt == 0
                    && n % t.nt == 0
                    && t.mt % 8 == 0
                    && t.nt % 8 == 0
                    && t.fits(bytes);
                if !legal {
                    return Err(format!("illegal tiling {t:?}"));
                }
            }
            Ok(())
        },
    );
}

// =================================================================
// End-to-end numerics on random problems (one config, small sizes)
// =================================================================

#[test]
fn prop_matmul_numerics_random_sizes() {
    check(
        &cfg(12, 0xE2E),
        |rng| {
            vec![
                rng.range(1, 6) * 8,
                rng.range(1, 6) * 8,
                rng.range(1, 6) * 8,
                rng.range(0, 4),
            ]
        },
        |v| {
            if v.len() < 4 {
                return Ok(());
            }
            let (m, n, k) = (v[0].max(8), v[1].max(8), v[2].max(8));
            let id = ConfigId::all()[v[3] % 5];
            let (a, b) = zerostall::kernels::test_matrices(
                m, n, k, 1234,
            );
            let r = zerostall::kernels::run_matmul(id, m, n, k, &a, &b)
                .map_err(|e| e.to_string())?;
            let want = zerostall::kernels::host_ref(m, n, k, &a, &b);
            for (g, w) in r.c.iter().zip(&want) {
                if (g - w).abs() > 1e-9 * w.abs().max(1.0) {
                    return Err(format!(
                        "numerics {m}x{n}x{k} on {}",
                        id.name()
                    ));
                }
            }
            // Conservation: one FPU op per MAC.
            if r.perf.fpu_ops_total != (m * n * k) as u64 {
                return Err(format!(
                    "fpu ops {} != {}",
                    r.perf.fpu_ops_total,
                    m * n * k
                ));
            }
            Ok(())
        },
    );
}

// =================================================================
// Buffer placement: the six double-buffered tiles never overlap
// =================================================================

/// Word address of tile element (row, col) under a BufDesc: rows are
/// `row_stride` apart, each row is a run of 8-word chunks spaced
/// `chunk_stride` apart.
fn buf_addr(
    d: &zerostall::kernels::layout::BufDesc,
    row: usize,
    col: usize,
) -> u32 {
    d.base
        + row as u32 * d.row_stride
        + (col / 8) as u32 * d.chunk_stride
        + (col % 8) as u32 * 8
}

#[test]
fn prop_plan_buffers_never_overlap() {
    check(
        &cfg(80, 0xB0F5),
        |rng| {
            vec![
                rng.range(1, 16) * 8, // m
                rng.range(1, 16) * 8, // n
                rng.range(1, 16) * 8, // k
                rng.range(0, 4),      // config index
                rng.range(0, 2),      // layout: grouped | linear+pad
            ]
        },
        |v| {
            if v.len() < 5 {
                return Ok(());
            }
            let (m, n, k) = (v[0].max(8), v[1].max(8), v[2].max(8));
            let id = ConfigId::all()[v[3] % 5];
            let layout = if v[4] % 2 == 0 {
                LayoutKind::Grouped
            } else {
                LayoutKind::Linear { pad_words: 1 }
            };
            let c = id.cluster_config();
            let Some(t) = choose_tiling(m, n, k, c.tcdm_bytes) else {
                return Err(format!("no tiling for {m}x{n}x{k}"));
            };
            let map = plan_buffers(&t, c.topology, c.tcdm_bytes, layout);
            let tcdm = Tcdm::new(c.topology, c.tcdm_bytes);
            let bufs = [
                (map.a[0], t.mt, t.k),
                (map.a[1], t.mt, t.k),
                (map.b[0], t.k, t.nt),
                (map.b[1], t.k, t.nt),
                (map.c[0], t.mt, t.nt),
                (map.c[1], t.mt, t.nt),
            ];
            let mut seen = std::collections::HashSet::new();
            let mut expected = 0usize;
            for (d, rows, cols) in bufs {
                for r in 0..rows {
                    for col in 0..cols {
                        let addr = buf_addr(&d, r, col);
                        if !tcdm.contains(addr) {
                            return Err(format!(
                                "OOB {addr:#x} ({m}x{n}x{k} {} {layout:?})",
                                id.name()
                            ));
                        }
                        if !seen.insert(addr) {
                            return Err(format!(
                                "overlap at {addr:#x} ({m}x{n}x{k} {} \
                                 {layout:?})",
                                id.name()
                            ));
                        }
                        expected += 1;
                    }
                }
            }
            if seen.len() != expected {
                return Err("address count mismatch".into());
            }
            Ok(())
        },
    );
}

// =================================================================
// Tiling: the chosen tiles cover M x K x N exactly (no MAC lost,
// none double-counted)
// =================================================================

#[test]
fn prop_tiling_covers_problem_exactly() {
    check(
        &cfg(300, 0xC0FE),
        |rng| {
            vec![
                rng.range(1, 16) * 8,
                rng.range(1, 16) * 8,
                rng.range(1, 16) * 8,
            ]
        },
        |v| {
            if v.len() < 3 {
                return Ok(());
            }
            let (m, n, k) = (v[0].max(8), v[1].max(8), v[2].max(8));
            for bytes in [96 * 1024, 128 * 1024] {
                let Some(t) = choose_tiling(m, n, k, bytes) else {
                    return Err(format!("no tiling {m}x{n}x{k}"));
                };
                let (gm, gn) = t.grid();
                if gm * t.mt != m || gn * t.nt != n {
                    return Err(format!(
                        "grid {gm}x{gn} of {}x{} tiles does not cover \
                         {m}x{n}",
                        t.mt, t.nt
                    ));
                }
                // K stays resident: per-pass MACs x passes == total.
                let macs =
                    t.passes() as u64 * (t.mt * t.nt * t.k) as u64;
                if macs != (m * n * k) as u64 {
                    return Err(format!(
                        "covered {macs} MACs, problem has {}",
                        m * n * k
                    ));
                }
            }
            Ok(())
        },
    );
}

// =================================================================
// Analytic backend: calibrated predictions track the cycle-accurate
// ground truth on a small randomized grid
// =================================================================

#[test]
fn prop_analytic_tracks_cycle_accurate() {
    use zerostall::coordinator::experiments::calibrate_on;
    use zerostall::coordinator::workload::Problem;

    // Fixed structural anchors (spread in outer-iteration count and
    // passes) plus randomized extra points.
    let mut grid = vec![
        Problem { m: 8, n: 8, k: 8 },
        Problem { m: 16, n: 16, k: 16 },
        Problem { m: 32, n: 32, k: 32 },
        Problem { m: 32, n: 16, k: 40 },
    ];
    let mut rng = Rng::new(0xCA11B);
    while grid.len() < 7 {
        let p = Problem {
            m: rng.range(1, 6) * 8,
            n: rng.range(1, 6) * 8,
            k: rng.range(1, 6) * 8,
        };
        if !grid.contains(&p) {
            grid.push(p);
        }
    }
    let out = calibrate_on(&grid, 2).unwrap();
    for e in &out.errors {
        assert!(
            e.mean_window_err < 0.20,
            "{}: mean window err {:.3} over {} points",
            e.config.name(),
            e.mean_window_err,
            e.points
        );
        assert!(
            e.max_window_err < 0.40,
            "{}: max window err {:.3}",
            e.config.name(),
            e.max_window_err
        );
        assert!(
            e.mean_util_err < 0.20,
            "{}: mean util err {:.3}",
            e.config.name(),
            e.mean_util_err
        );
    }
}

// =================================================================
// Differential: the calibrated analytic backend tracks the
// cycle-accurate backend on seeded random *fused* and *sharded*
// GemmJobs. Failures shrink to a minimal job spec and the panic
// carries the replay seed (PROP_SEED) and case index.
// =================================================================

#[test]
fn prop_analytic_tracks_cycle_on_random_fused_sharded_jobs() {
    use zerostall::backend::{fit_calibration, CalSample};
    use zerostall::fabric::FabricConfig;
    use zerostall::kernels::{
        Activation, Epilogue, GemmJob, GemmService,
    };

    let config = ConfigId::Zonl48Db;
    let cycle = GemmService::cycle();
    let epis = [
        Epilogue::NONE,
        Epilogue { bias: true, act: None },
        Epilogue { bias: true, act: Some(Activation::Relu) },
        Epilogue { bias: true, act: Some(Activation::Gelu) },
    ];

    // Calibrate against cycle-accurate ground truth on fixed plain +
    // fused anchors spanning the tested size range.
    let anchors = [
        (16usize, 16usize, 16usize),
        (32, 32, 32),
        (32, 16, 40),
        (24, 48, 16),
        (40, 40, 24),
        (16, 32, 32),
        (48, 24, 16),
        (32, 32, 16),
    ];
    let samples: Vec<CalSample> = anchors
        .iter()
        .enumerate()
        .map(|(i, &(m, n, k))| {
            let job = GemmJob::fused(
                config,
                m,
                n,
                k,
                LayoutKind::Grouped,
                epis[i % epis.len()],
            );
            CalSample::from_result(&cycle.run_job(&job).unwrap())
        })
        .collect();
    let ana = GemmService::analytic_with(fit_calibration(&samples));

    // Cycle-accurate cases are expensive; scale the count down from
    // PROP_CASES rather than pinning it so CI's nightly widening
    // still reaches this suite.
    let base = Config::default();
    let cases = (base.cases / 8).max(6);
    check(
        &cfg(cases, base.seed ^ 0xD1FF),
        |rng| {
            vec![
                rng.range(2, 5), // m/8
                rng.range(2, 5), // n/8
                rng.range(2, 5), // k/8
                rng.range(0, 3), // epilogue selector
                rng.range(0, 2), // fabric selector
            ]
        },
        |v| {
            if v.len() < 5 {
                return Ok(());
            }
            let clusters = [1usize, 2, 4][v[4] % 3];
            let mut m = 8 * v[0].clamp(2, 5);
            let mut n = 8 * v[1].clamp(2, 5);
            let k = 8 * v[2].clamp(2, 5);
            if clusters > 1 {
                // Keep shards on sane tile sizes: tiny shards sit in
                // the fixed-overhead regime where a first-order model
                // is not expected to be tight.
                m = m.max(32);
                n = n.max(32);
            }
            let epi = epis[v[3] % epis.len()];
            let job =
                GemmJob::fused(config, m, n, k, LayoutKind::Grouped, epi);
            let (got, want) = if clusters == 1 {
                let c = cycle.run_job(&job).map_err(|e| e.to_string())?;
                let a = ana.run_job(&job).map_err(|e| e.to_string())?;
                (a.perf.window_cycles, c.perf.window_cycles)
            } else {
                let fab = FabricConfig::new(clusters);
                let c = cycle
                    .run_sharded_job(&job, &fab)
                    .map_err(|e| e.to_string())?;
                let a = ana
                    .run_sharded_job(&job, &fab)
                    .map_err(|e| e.to_string())?;
                (a.window_cycles(), c.window_cycles())
            };
            let err = (got as f64 - want as f64).abs()
                / want.max(1) as f64;
            let bound = if clusters == 1 { 0.45 } else { 0.55 };
            if err > bound {
                return Err(format!(
                    "{m}x{n}x{k} epi={} clusters={clusters}: window \
                     err {err:.3} beyond the calibrated bound \
                     {bound} (analytic {got} vs cycle {want})",
                    epi.name()
                ));
            }
            Ok(())
        },
    );
}

// =================================================================
// StallScope: the conservation invariant `useful + Σstalls == cycles`
// holds bit-exactly per core on random fused + sharded jobs across
// the evaluation space, and the Useful bucket always equals the FPU
// op count (so the decomposition can never drift from the headline
// utilization metric). Failures shrink to a minimal job spec and the
// panic carries the replay seed (PROP_SEED) and case index.
// =================================================================

#[test]
fn prop_stallscope_conservation_on_random_fused_sharded_jobs() {
    use zerostall::fabric::FabricConfig;
    use zerostall::kernels::{
        Activation, Epilogue, GemmJob, GemmService,
    };
    use zerostall::profile::StallProfile;

    let cycle = GemmService::cycle();
    let epis = [
        Epilogue::NONE,
        Epilogue { bias: true, act: None },
        Epilogue { bias: true, act: Some(Activation::Relu) },
        Epilogue { bias: true, act: Some(Activation::Gelu) },
    ];
    // Cycle-accurate cases are expensive; scale down from PROP_CASES
    // like the analytic differential above.
    let base = Config::default();
    let cases = (base.cases / 8).max(6);
    check(
        &cfg(cases, base.seed ^ 0x57A11),
        |rng| {
            vec![
                rng.range(1, 5), // m/8
                rng.range(1, 5), // n/8
                rng.range(1, 5), // k/8
                rng.range(0, 4), // epilogue selector
                rng.range(0, 3), // fabric selector
                rng.range(0, 5), // config selector
            ]
        },
        |v| {
            if v.len() < 6 {
                return Ok(());
            }
            let clusters = [1usize, 2, 4][v[4] % 3];
            let m = 8 * v[0].clamp(1, 5);
            let n = 8 * v[1].clamp(1, 5);
            let k = 8 * v[2].clamp(1, 5);
            let epi = epis[v[3] % epis.len()];
            let id = ConfigId::all()[v[5] % 5];
            let job =
                GemmJob::fused(id, m, n, k, LayoutKind::Grouped, epi);
            let check_profile = |s: &StallProfile,
                                 fpu_ops: u64,
                                 what: &str|
             -> Result<(), String> {
                s.check_conservation().map_err(|e| {
                    format!("{what} {m}x{n}x{k} on {}: {e}", id.name())
                })?;
                if s.useful_total() != fpu_ops {
                    return Err(format!(
                        "{what} {m}x{n}x{k} on {}: useful {} != \
                         fpu_ops {fpu_ops}",
                        id.name(),
                        s.useful_total()
                    ));
                }
                Ok(())
            };
            if clusters == 1 {
                let r =
                    cycle.run_job(&job).map_err(|e| e.to_string())?;
                check_profile(
                    &r.perf.stalls,
                    r.perf.fpu_ops_total,
                    "job",
                )?;
            } else {
                let fr = cycle
                    .run_sharded_job(&job, &FabricConfig::new(clusters))
                    .map_err(|e| e.to_string())?;
                for (si, s) in fr.shards.iter().enumerate() {
                    check_profile(
                        &s.perf.stalls,
                        s.perf.fpu_ops_total,
                        &format!("shard {si} of {clusters}"),
                    )?;
                }
                let merged = fr.stall_profile();
                check_profile(
                    &merged,
                    fr.fpu_ops_total(),
                    "merged fabric",
                )?;
            }
            Ok(())
        },
    );
}

// Tiling type needs Debug for failures; silence unused warnings.
#[allow(dead_code)]
fn _t(_: Tiling) {}
