//! ServeSim property + integration tests: bit-for-bit determinism
//! across runs and thread counts, trace shrinking, the FIFO vs
//! continuous-batching latency invariant at low rate, and the
//! acceptance claim that continuous batching sustains higher
//! SLO-attained throughput than FIFO at the same offered rate.

use zerostall::coordinator::report;
use zerostall::coordinator::serve::{
    gen_arrivals, isolated_latency, serve, serve_trace, ArrivalTrace,
    Policy, ServeConfig, ServeEngine,
};
use zerostall::kernels::GemmService;
use zerostall::util::prop::{check, Config};

fn analytic() -> GemmService {
    GemmService::analytic()
}

fn cfg_of(models: &[&str]) -> ServeConfig {
    let mut c = ServeConfig::new(
        models.iter().map(|s| s.to_string()).collect(),
    );
    c.slo = Some(u64::MAX);
    c
}

// =================================================================
// Determinism: with a fixed seed the serve report is bit-for-bit
// identical across runs and across backend thread counts.
// =================================================================

#[test]
fn prop_serve_report_deterministic_across_runs_and_threads() {
    let base = Config::default();
    check(
        &Config { cases: base.cases, seed: base.seed ^ 0x5E57E },
        |rng| {
            vec![
                rng.range(1, 6),      // requests
                rng.range(1, 3),      // clusters
                rng.range(0, 1),      // policy
                rng.range(1, 40),     // rate (req/Mcycle)
                rng.range(0, 1),      // bursty?
                rng.range(0, 2),      // model mix
                rng.range(0, 10_000), // seed
            ]
        },
        |v| {
            if v.len() < 7 {
                return Ok(());
            }
            let models: &[&str] = match v[5] % 3 {
                0 => &["ffn"],
                1 => &["qkv"],
                _ => &["ffn", "mlp"],
            };
            let mut cfg = cfg_of(models);
            cfg.requests = (v[0] % 6).max(1);
            cfg.clusters = (v[1] % 3).max(1);
            cfg.policy = if v[2] % 2 == 0 {
                Policy::Fifo
            } else {
                Policy::Continuous
            };
            cfg.rate_per_mcycle = ((v[3] % 40).max(1)) as f64;
            cfg.burst = if v[4] % 2 == 0 { 0.0 } else { 0.5 };
            cfg.seed = v[6] as u64;
            let mut runs = Vec::new();
            for threads in [1usize, 4] {
                let mut c = cfg.clone();
                c.threads = threads;
                let svc = analytic();
                runs.push(serve(&svc, &c).map_err(|e| e.to_string())?);
            }
            if runs[0] != runs[1] {
                return Err(
                    "serve run differs across thread counts".into()
                );
            }
            if report::render_serve(&runs[0].report)
                != report::render_serve(&runs[1].report)
            {
                return Err("rendered report differs".into());
            }
            if report::serve_csv(&runs[0]).to_string()
                != report::serve_csv(&runs[1]).to_string()
            {
                return Err("per-request CSV differs".into());
            }
            // Run-to-run replay on a fresh service.
            let mut c = cfg.clone();
            c.threads = 4;
            let svc = analytic();
            let again = serve(&svc, &c).map_err(|e| e.to_string())?;
            if again != runs[1] {
                return Err("replay with same seed differs".into());
            }
            Ok(())
        },
    );
}

#[test]
fn serve_cycle_backend_is_deterministic_too() {
    // The cycle backend actually simulates every GEMM, so keep this
    // one small: 2 ffn requests, 2 clusters, thread counts 1 vs 2.
    let mut cfg = cfg_of(&["ffn"]);
    cfg.requests = 2;
    cfg.clusters = 2;
    cfg.policy = Policy::Continuous;
    cfg.rate_per_mcycle = 50.0;
    cfg.seed = 99;
    let mut runs = Vec::new();
    for threads in [1usize, 2] {
        let mut c = cfg.clone();
        c.threads = threads;
        let svc = GemmService::cycle();
        runs.push(serve(&svc, &c).unwrap());
    }
    assert_eq!(runs[0], runs[1], "cycle-backend serve must not wobble");
    assert_eq!(runs[0].report.completed, 2);
}

// =================================================================
// Conservation invariants + shrinkable arrival traces: any shrunk
// trace still serves cleanly and the accounting stays consistent.
// =================================================================

#[test]
fn prop_serve_conservation_over_shrinkable_traces() {
    let base = Config::default();
    let mut cfg = cfg_of(&["ffn", "qkv"]);
    cfg.clusters = 2;
    cfg.policy = Policy::Continuous;
    cfg.rate_per_mcycle = 25.0;
    cfg.burst = 0.3;
    let gen_cfg = cfg.clone();
    check(
        &Config {
            cases: (base.cases / 4).max(8),
            seed: base.seed ^ 0xC0A5,
        },
        move |rng| {
            let mut c = gen_cfg.clone();
            c.requests = rng.range(1, 8);
            c.seed = rng.next_u64();
            gen_arrivals(&c)
        },
        |trace: &ArrivalTrace| {
            let svc = analytic();
            let run = serve_trace(&svc, &cfg, trace)
                .map_err(|e| e.to_string())?;
            let r = &run.report;
            if r.completed != trace.requests.len() {
                return Err(format!(
                    "{} of {} requests completed",
                    r.completed,
                    trace.requests.len()
                ));
            }
            if r.latency.count() != r.completed as u64 {
                return Err("histogram count != completed".into());
            }
            if run.rows.len() != r.completed {
                return Err("rows != completed".into());
            }
            if r.slo_attained > r.completed {
                return Err("SLO attainment above completion".into());
            }
            for (ci, &b) in r.per_cluster_busy.iter().enumerate() {
                if b > r.makespan_cycles {
                    return Err(format!(
                        "cluster {ci} busier ({b}) than the makespan \
                         ({})",
                        r.makespan_cycles
                    ));
                }
            }
            for row in &run.rows {
                if row.completion < row.arrival {
                    return Err(format!(
                        "request {} completed before arriving",
                        row.id
                    ));
                }
                if row.latency
                    != row.completion - row.arrival
                {
                    return Err("latency != completion - arrival".into());
                }
            }
            if r.total_ops < r.gemm_ops {
                return Err("more GEMMs than ops".into());
            }
            Ok(())
        },
    );
}

// =================================================================
// Policy invariant: at low offered rate, continuous batching never
// increases p50 latency over FIFO (it only removes waiting and may
// shard lone waves).
// =================================================================

#[test]
fn cb_never_increases_p50_latency_at_low_rate() {
    for seed in [7u64, 21, 1234] {
        let mut cfg = cfg_of(&["ffn"]);
        cfg.clusters = 2;
        cfg.requests = 12;
        cfg.seed = seed;
        let iso = isolated_latency(&analytic(), &cfg, 0).unwrap();
        // Mean gap of 50 isolated latencies: overlap is rare, queues
        // stay empty — the regime where FIFO is at its best.
        cfg.rate_per_mcycle = 1.0e6 / (50.0 * iso as f64);
        cfg.policy = Policy::Fifo;
        let fifo = serve(&analytic(), &cfg).unwrap();
        cfg.policy = Policy::Continuous;
        let cb = serve(&analytic(), &cfg).unwrap();
        assert_eq!(fifo.report.completed, 12);
        assert_eq!(cb.report.completed, 12);
        assert!(
            cb.report.p50() <= fifo.report.p50(),
            "seed {seed}: cb p50 {} > fifo p50 {}",
            cb.report.p50(),
            fifo.report.p50()
        );
    }
}

// =================================================================
// Acceptance: on the ffn zoo model, continuous batching sustains
// measurably higher SLO-attained throughput than FIFO at the same
// offered arrival rate.
// =================================================================

#[test]
fn cb_sustains_higher_slo_throughput_than_fifo_on_ffn() {
    let mut cfg = cfg_of(&["ffn"]);
    cfg.clusters = 4;
    cfg.requests = 40;
    cfg.seed = 2026;
    // Offered load: two requests per isolated service time — twice
    // what strict FIFO can drain; well within what 4 clusters of
    // continuous batching can.
    let iso = isolated_latency(&analytic(), &cfg, 0).unwrap();
    cfg.rate_per_mcycle = 2.0e6 / iso as f64;
    cfg.slo = Some(3 * iso);

    cfg.policy = Policy::Fifo;
    let fifo = serve(&analytic(), &cfg).unwrap();
    cfg.policy = Policy::Continuous;
    let cb = serve(&analytic(), &cfg).unwrap();

    assert_eq!(fifo.report.completed, 40);
    assert_eq!(cb.report.completed, 40);
    // FIFO is overloaded: its queue grows and late requests blow the
    // SLO; continuous batching keeps the fabric fed.
    assert!(
        cb.report.slo_attained > fifo.report.slo_attained,
        "cb attained {} <= fifo attained {}",
        cb.report.slo_attained,
        fifo.report.slo_attained
    );
    assert!(
        cb.report.slo_attained_throughput()
            > 1.3 * fifo.report.slo_attained_throughput(),
        "cb {:.4} req/Mcycle vs fifo {:.4} req/Mcycle",
        cb.report.slo_attained_throughput(),
        fifo.report.slo_attained_throughput()
    );
    assert!(
        cb.report.makespan_cycles < fifo.report.makespan_cycles,
        "continuous batching must drain the same trace sooner"
    );
    // And the win shows up in plain sustained throughput too.
    assert!(
        cb.report.throughput_per_mcycle()
            > fifo.report.throughput_per_mcycle()
    );
}

// =================================================================
// Churn: a mixed-model stream exercises the plan cache; repeated
// shapes must hit and the serve-reported rate must be exact.
// =================================================================

#[test]
fn plan_cache_hit_rate_under_churn_is_exact() {
    let svc = analytic();
    let mut cfg = cfg_of(&["ffn", "qkv", "mlp"]);
    cfg.requests = 24;
    cfg.clusters = 2;
    cfg.policy = Policy::Continuous;
    cfg.rate_per_mcycle = 40.0;
    cfg.seed = 5;
    let run = serve(&svc, &cfg).unwrap();
    let s = run.report.plan_stats;
    // Exactness: every GEMM dispatch is one hit or one miss, and each
    // distinct (shape, epilogue) plan misses exactly once.
    assert_eq!(s.plan_hits + s.plan_misses, run.report.gemm_ops);
    assert!(s.plan_misses > 0);
    // The three-model mix has 6 distinct full GEMM plans; lone-wave
    // tensor-parallel dispatches can add at most one shard-shaped
    // plan each on a fixed fabric, so the cache never exceeds 12.
    assert!(
        s.plan_misses <= 12,
        "more misses than distinct plans possible: {s:?}"
    );
    assert!(
        s.hit_rate() > 0.5,
        "24 requests over <= 12 plans must mostly hit: {s:?}"
    );
    // Replaying on the warm service is pure hits.
    let again = serve(&svc, &cfg).unwrap();
    assert_eq!(again.report.plan_stats.plan_misses, 0);
}

// =================================================================
// MegaServe differential: the event-driven core must be bit-identical
// to the wave-synchronous loop on random traces — report AND rows —
// for both policies. This property gates the legacy path's removal.
// =================================================================

#[test]
fn prop_event_engine_matches_legacy_on_random_traces() {
    let base = Config::default();
    let mut gen_cfg = cfg_of(&["ffn", "qkv"]);
    gen_cfg.rate_per_mcycle = 30.0;
    gen_cfg.burst = 0.4;
    let arrivals_cfg = gen_cfg.clone();
    check(
        &Config {
            cases: (base.cases / 4).max(8),
            seed: base.seed ^ 0xE7E27,
        },
        move |rng| {
            let mut c = arrivals_cfg.clone();
            c.requests = rng.range(0, 8);
            c.seed = rng.next_u64();
            // The trace carries the knob choices in-band so shrinking
            // stays meaningful: policy/clusters/SLO derive from the
            // first request's seed below.
            gen_arrivals(&c)
        },
        |trace: &ArrivalTrace| {
            let knobs =
                trace.requests.first().map(|r| r.seed).unwrap_or(0);
            let mut cfg = cfg_of(&["ffn", "qkv"]);
            cfg.rate_per_mcycle = 30.0;
            cfg.burst = 0.4;
            cfg.clusters = 1 + (knobs % 3) as usize;
            cfg.policy = if knobs & 4 == 0 {
                Policy::Fifo
            } else {
                Policy::Continuous
            };
            // Exercise the derived-SLO probe path too: its plan-cache
            // and memo accounting must fold in identically.
            cfg.slo = if knobs & 8 == 0 {
                None
            } else {
                Some(u64::MAX)
            };
            cfg.engine = ServeEngine::Event;
            let ev = serve_trace(&analytic(), &cfg, trace)
                .map_err(|e| e.to_string())?;
            cfg.engine = ServeEngine::Legacy;
            let lg = serve_trace(&analytic(), &cfg, trace)
                .map_err(|e| e.to_string())?;
            // Compare report + rows + models (not engine_stats — the
            // legacy loop keeps no event counters by construction).
            if ev.report != lg.report {
                return Err(format!(
                    "reports differ:\nevent  {:?}\nlegacy {:?}",
                    ev.report, lg.report
                ));
            }
            if ev.rows != lg.rows {
                return Err("per-request rows differ".into());
            }
            if ev.models != lg.models {
                return Err("model tables differ".into());
            }
            if report::serve_csv(&ev).to_string()
                != report::serve_csv(&lg).to_string()
            {
                return Err("rendered CSV differs".into());
            }
            Ok(())
        },
    );
}

// =================================================================
// Acceptance scale check: a mixed-zoo trace through the event core is
// bit-identical across 1/2/8 host threads (whole ServeRun, including
// the event/memo counters), at a size where waves genuinely overlap.
// =================================================================

#[test]
fn event_engine_is_deterministic_across_1_2_8_threads() {
    let mut cfg = cfg_of(&["ffn", "qkv", "mlp"]);
    cfg.requests = 300;
    cfg.clusters = 4;
    cfg.policy = Policy::Continuous;
    cfg.rate_per_mcycle = 80.0;
    cfg.burst = 0.3;
    cfg.seed = 0xACCE55;
    let mut runs = Vec::new();
    for threads in [1usize, 2, 8] {
        let mut c = cfg.clone();
        c.threads = threads;
        runs.push(serve(&analytic(), &c).unwrap());
    }
    assert_eq!(runs[0], runs[1], "1 vs 2 threads");
    assert_eq!(runs[1], runs[2], "2 vs 8 threads");
    let run = &runs[0];
    assert_eq!(run.report.completed, 300);
    // The memo does real work at this scale: nearly every dispatch
    // replays (three models contribute a handful of distinct shapes).
    let es = run.engine_stats;
    assert!(es.memo_misses > 0);
    assert!(
        es.memo_hits > 20 * es.memo_misses,
        "steady-state dispatches must come from the memo: {es:?}"
    );
    assert_eq!(
        es.memo_hits + es.memo_misses,
        run.report.gemm_ops,
        "every GEMM dispatch is exactly one memo hit or miss"
    );
}
