//! TimeScope tests: telemetry determinism (bit-identical registries
//! and digests across host thread counts and FastPath settings), the
//! shard-merge commutativity property, fault-window signal visibility
//! (utilization dip + queue-depth spike), and the autoscaler
//! acceptance claim (no extra sheds, fewer provisioned fabric-cycles
//! than fixed provisioning at the same offered rate).
//!
//! Virtual-time windowing makes every signal a pure function of the
//! event stream, so telemetry must never perturb outcomes: each test
//! that turns the registry on also pins the outcome rows against a
//! telemetry-off twin.

use zerostall::backend::BackendKind;
use zerostall::coordinator::node::{
    run_digest, run_node, AutoscalePolicy, FaultEvent, FaultPlan,
    NodeConfig, RouterPolicy,
};
use zerostall::coordinator::serve::{
    serve, solo_latency, Policy, ServeConfig,
};
use zerostall::kernels::GemmService;
use zerostall::profile::telemetry::{SpanKind, Telemetry};
use zerostall::util::prop::{check, Config, Shrink};
use zerostall::util::stats::Fnv64;

fn serve_cfg(models: &[&str], clusters: usize) -> ServeConfig {
    let mut c = ServeConfig::new(
        models.iter().map(|s| s.to_string()).collect(),
    );
    c.clusters = clusters;
    c.slo = Some(u64::MAX);
    c.seed = 2026;
    c
}

fn rate_for_load(rho: f64, fabrics: usize, mean_cost: u64) -> f64 {
    rho * fabrics as f64 * 1.0e6 / mean_cost as f64
}

fn mean_cost(svc: &GemmService, cfg: &ServeConfig) -> u64 {
    let costs: Vec<u64> = (0..cfg.models.len())
        .map(|mi| {
            solo_latency(svc, cfg, mi, Policy::Continuous).unwrap()
        })
        .collect();
    (costs.iter().sum::<u64>() / costs.len() as u64).max(1)
}

// =================================================================
// Determinism: the full telemetry registry (counters, gauges,
// histograms, spans) and the folded digest must be bit-identical
// across 1/2/8 host threads on the acceptance-scale node run.
// =================================================================

#[test]
fn node_telemetry_bit_identical_across_threads_100k() {
    let requests = 100_000usize;
    let svc = GemmService::analytic();
    let mut base = serve_cfg(&["ffn", "qkv"], 4);
    base.requests = requests;
    let cost = mean_cost(&svc, &base);
    base.rate_per_mcycle = rate_for_load(0.6, 4, cost);
    base.burst = 0.2;
    base.telemetry = Some(32 * cost);
    let span = requests as f64 * 1.0e6 / base.rate_per_mcycle;

    let mut runs = Vec::new();
    for threads in [1usize, 2, 8] {
        let mut scfg = base.clone();
        scfg.threads = threads;
        let mut cfg = NodeConfig::new(scfg, 4);
        cfg.router = RouterPolicy::PowerOfTwo;
        cfg.faults = FaultPlan {
            events: vec![FaultEvent {
                at: (span / 3.0) as u64,
                fabric: 1,
                restore: Some((2.0 * span / 3.0) as u64),
            }],
        };
        runs.push(run_node(&svc, &cfg).unwrap());
    }
    let tel = runs[0].telemetry.as_ref().expect("telemetry enabled");
    assert!(tel.series_count() > 0);
    assert!(!tel.spans().is_empty());
    for run in &runs[1..] {
        assert_eq!(
            runs[0], *run,
            "telemetry-on node run differs across host thread counts"
        );
    }
    // The digest is recomputable: base outcome digest, then the
    // registry folded on top.
    let mut h = Fnv64::new();
    h.write_u64(run_digest(&runs[0].rows, &runs[0].sheds));
    tel.fold(&mut h);
    assert_eq!(runs[0].report.digest, h.finish());
    // And equals the registry's own standalone digest discipline.
    assert_eq!(tel.digest(), runs[1].telemetry.as_ref().unwrap().digest());
}

#[test]
fn node_telemetry_invariant_to_fast_forward_on_cycle_backend() {
    // The cycle backend actually simulates the per-model cost
    // probes; FastPath bit-exactness must carry through into an
    // identical telemetry registry, not just identical outcome rows.
    let requests = 10_000usize;
    let mut base = serve_cfg(&["ffn"], 2);
    base.requests = requests;
    base.rate_per_mcycle = 30.0;
    base.burst = 0.1;
    base.telemetry = Some(2_000_000);
    let mut runs = Vec::new();
    for (threads, ff) in [(2usize, true), (1, true), (2, false)] {
        let mut scfg = base.clone();
        scfg.threads = threads;
        let mut cfg = NodeConfig::new(scfg, 4);
        cfg.router = RouterPolicy::LeastLoaded;
        let svc = GemmService::of_kind_ff(BackendKind::Cycle, ff);
        runs.push(run_node(&svc, &cfg).unwrap());
    }
    assert_eq!(runs[0], runs[1], "telemetry differs across threads");
    assert_eq!(runs[0], runs[2], "telemetry differs across fast-forward");
    assert!(runs[0].telemetry.is_some());
}

// =================================================================
// Signal visibility: a mid-trace fabric outage must appear in the
// windowed series as a utilization dip on the dead fabric and a
// queue-depth spike on the survivors, and the downtime counter must
// conserve the report's downtime cycles exactly.
// =================================================================

#[test]
fn fault_window_shows_utilization_dip_and_queue_spike() {
    let requests = 20_000usize;
    let svc = GemmService::analytic();
    let mut base = serve_cfg(&["ffn", "qkv"], 4);
    base.requests = requests;
    let cost = mean_cost(&svc, &base);
    // rho = 0.8 on 4 fabrics: losing one pushes the survivors past
    // saturation, so the queue must grow for the whole outage.
    base.rate_per_mcycle = rate_for_load(0.8, 4, cost);
    base.burst = 0.2;
    let span = requests as f64 * 1.0e6 / base.rate_per_mcycle;
    let down_at = (span / 3.0) as u64;
    let restore = (2.0 * span / 3.0) as u64;
    // ~10 windows fully inside the outage.
    base.telemetry = Some(((restore - down_at) / 10).max(1));

    let mut cfg = NodeConfig::new(base, 4);
    cfg.router = RouterPolicy::PowerOfTwo;
    cfg.faults = FaultPlan {
        events: vec![FaultEvent {
            at: down_at,
            fabric: 1,
            restore: Some(restore),
        }],
    };
    let run = run_node(&svc, &cfg).unwrap();
    let tel = run.telemetry.as_ref().unwrap();
    let w = tel.window();
    assert_eq!(run.report.shed_total(), 0);

    // Exact conservation: the windowed downtime counter re-adds to
    // the report's downtime cycle total.
    assert_eq!(
        tel.counter_total("fabric_downtime_cycles", "fabric=1"),
        run.report.per_fabric[1].downtime,
    );

    // Windows fully inside the outage: the dead fabric completes
    // nothing and its utilization gauge reads zero.
    let first_in = down_at / w + 1; // first window starting after down
    let last_in = restore / w; // windows [first_in, last_in) end before restore
    assert!(
        first_in + 3 <= last_in,
        "outage too short for windowed assertions: [{first_in},{last_in})"
    );
    for win in first_in..last_in {
        assert_eq!(
            tel.counter_window("completions", "fabric=1", win),
            0,
            "dead fabric completed work in window {win}"
        );
        if let Some(cell) = tel.gauge_window("util_permille", "fabric=1", win)
        {
            assert_eq!(
                cell.max, 0,
                "dead fabric shows utilization in window {win}"
            );
        }
    }
    // The fabric did real work outside the outage.
    assert!(tel.counter_total("completions", "fabric=1") > 0);

    // Queue-depth spike: the node-wide backlog during the outage
    // dwarfs the steady-state backlog before it.
    let depth_max = |win: u64| {
        tel.gauge_window("queue_depth", "node", win)
            .map(|c| c.max)
            .unwrap_or(0)
    };
    let pre = (0..first_in.saturating_sub(1)).map(depth_max).max().unwrap_or(0);
    let spike = (first_in..last_in).map(depth_max).max().unwrap_or(0);
    assert!(
        spike > pre,
        "no queue-depth spike during outage: {spike} <= {pre}"
    );

    // An Outage span covering the fault is in the span stream.
    assert!(run
        .telemetry
        .as_ref()
        .unwrap()
        .spans()
        .iter()
        .any(|s| s.kind == SpanKind::Outage
            && s.pid == 1
            && s.start == down_at));
}

// =================================================================
// Serve event core: telemetry is observability only — the outcome
// rows are identical with the registry on or off, and the counters
// conserve the request stream.
// =================================================================

#[test]
fn serve_telemetry_conserves_streams_and_never_perturbs_rows() {
    let svc = GemmService::analytic();
    let mut on = serve_cfg(&["ffn", "qkv"], 2);
    on.requests = 400;
    on.rate_per_mcycle = 40.0;
    on.telemetry = Some(500_000);
    let mut off = on.clone();
    off.telemetry = None;

    let a = serve(&svc, &on).unwrap();
    let b = serve(&svc, &off).unwrap();
    assert!(b.telemetry.is_none());
    assert_eq!(a.rows, b.rows, "telemetry perturbed serve outcomes");
    assert_eq!(a.report, b.report);

    let tel = a.telemetry.as_ref().expect("telemetry enabled");
    assert_eq!(tel.counter_total("arrivals", "") as usize, on.requests);
    assert_eq!(
        tel.counter_total("completions", "") as usize,
        a.rows.len()
    );
    // Explicit SLO, so no derived-SLO probe ran and the engine-stat
    // totals are exactly the per-wave telemetry deltas.
    assert_eq!(
        a.engine_stats.memo_hits,
        tel.counter_total("memo_hits", ""),
    );
    assert_eq!(
        a.engine_stats.memo_misses,
        tel.counter_total("memo_misses", ""),
    );
    // One Request lifecycle span per completed request, one Wave
    // span per dispatched wave.
    let reqs = tel
        .spans()
        .iter()
        .filter(|s| s.kind == SpanKind::Request)
        .count();
    assert_eq!(reqs, a.rows.len());
    let waves = tel
        .spans()
        .iter()
        .filter(|s| s.kind == SpanKind::Wave)
        .count() as u64;
    assert_eq!(waves, tel.counter_total("waves", ""));
}

// =================================================================
// Shard-merge discipline: merging per-shard registries is exact and
// commutative — any partition of the event stream into shards, merged
// in any order, folds to the same digest as single-shard recording.
// =================================================================

#[derive(Clone, Debug)]
struct TelEvents {
    /// `(time, kind%4, value)`: 0=count, 1=gauge, 2=observe, 3=span.
    events: Vec<(u64, u64, u64)>,
}

impl Shrink for TelEvents {
    fn shrinks(&self) -> Vec<Self> {
        self.events
            .shrinks()
            .into_iter()
            .map(|events| TelEvents { events })
            .collect()
    }
}

fn record(tel: &mut Telemetry, ev: &(u64, u64, u64)) {
    let (t, kind, v) = *ev;
    match kind % 4 {
        0 => tel.count("hits", "fabric=0", t, v % 7 + 1),
        1 => tel.gauge("depth", "node", t, v % 100),
        2 => tel.observe("latency", "", t, v),
        _ => tel.span(SpanKind::Wave, 0, v, t, t + v % 1000, v % 3),
    }
}

#[test]
fn prop_shard_merge_is_exact_and_commutative() {
    let window = 1_000u64;
    check(
        &Config::default(),
        |r| {
            let n = r.range(0, 60);
            TelEvents {
                events: (0..n)
                    .map(|_| {
                        (r.below(20_000), r.below(4), r.below(5_000))
                    })
                    .collect(),
            }
        },
        |input| {
            let end = input
                .events
                .iter()
                .map(|&(t, _, v)| t + v % 1000)
                .max()
                .unwrap_or(0);
            // Single-shard reference.
            let mut whole = Telemetry::new(window);
            for ev in &input.events {
                record(&mut whole, ev);
            }
            whole.seal(end);
            // Three shards by round-robin, merged in two orders.
            for order in [[0usize, 1, 2], [2, 0, 1]] {
                let mut shards = vec![
                    Telemetry::new(window),
                    Telemetry::new(window),
                    Telemetry::new(window),
                ];
                for (i, ev) in input.events.iter().enumerate() {
                    record(&mut shards[i % 3], ev);
                }
                let mut merged = Telemetry::new(window);
                for &s in &order {
                    merged.merge(&shards[s]);
                }
                merged.seal(end);
                if merged != whole {
                    return Err(format!(
                        "shard merge (order {order:?}) diverged from \
                         single-shard recording"
                    ));
                }
                if merged.digest() != whole.digest() {
                    return Err("merge digest diverged".into());
                }
            }
            Ok(())
        },
    );
}

// =================================================================
// Window boundaries: events on exact window edges, zero-length runs,
// and trailing partial windows.
// =================================================================

#[test]
fn window_boundary_assignment_is_half_open() {
    let mut tel = Telemetry::new(100);
    // t = 99 is the last cycle of window 0; t = 100 opens window 1.
    tel.count("c", "", 99, 1);
    tel.count("c", "", 100, 1);
    tel.seal(150);
    assert_eq!(tel.counter_window("c", "", 0), 1);
    assert_eq!(tel.counter_window("c", "", 1), 1);
    // Trailing partial window [100, 150) still reads back.
    assert_eq!(tel.last_window(), 1);
    // A span crossing the boundary splits window-exactly.
    let mut tel2 = Telemetry::new(100);
    tel2.count_span("busy", "", 50, 250);
    tel2.seal(250);
    assert_eq!(tel2.counter_window("busy", "", 0), 50);
    assert_eq!(tel2.counter_window("busy", "", 1), 100);
    assert_eq!(tel2.counter_window("busy", "", 2), 50);
    assert_eq!(tel2.counter_total("busy", ""), 200);
}

#[test]
fn zero_length_run_has_no_windows() {
    let mut tel = Telemetry::new(100);
    tel.seal(0);
    assert_eq!(tel.end(), 0);
    assert_eq!(tel.last_window(), 0);
    assert_eq!(tel.counter_window("anything", "", 0), 0);
    assert!(tel.spans().is_empty());
    // Two empty registries agree bit-for-bit.
    let mut other = Telemetry::new(100);
    other.seal(0);
    assert_eq!(tel.digest(), other.digest());
}

// =================================================================
// Autoscaler acceptance: reading only windowed gauges, the policy
// must shed no more than fixed provisioning at the same offered rate
// while spending fewer provisioned fabric-cycles.
// =================================================================

#[test]
fn autoscaler_beats_fixed_provisioning_on_idle_cycles() {
    let requests = 2_000usize;
    let svc = GemmService::analytic();
    let mut base = serve_cfg(&["ffn"], 2);
    base.requests = requests;
    let cost = mean_cost(&svc, &base);
    // Light load: ~15% of a 4-fabric node. Fixed provisioning keeps
    // 4 fabrics hot; the autoscaler should park most of them.
    base.rate_per_mcycle = rate_for_load(0.15, 4, cost);

    let fixed_cfg = NodeConfig::new(base.clone(), 4);
    let fixed = run_node(&svc, &fixed_cfg).unwrap();

    let mut auto_cfg = NodeConfig::new(base, 4);
    auto_cfg.autoscale =
        Some(AutoscalePolicy::parse("low=0.3,high=0.9,cooldown=2").unwrap());
    let auto_run = run_node(&svc, &auto_cfg).unwrap();
    let tel = auto_run.telemetry.as_ref().expect("autoscale implies tel");

    assert!(
        tel.counter_total("autoscale_park", "") > 0,
        "light load never triggered a park"
    );
    assert!(auto_run.report.shed_total() <= fixed.report.shed_total());
    assert_eq!(
        auto_run.report.completed + auto_run.report.shed_total(),
        requests,
        "autoscaling lost requests"
    );
    assert!(
        auto_run.report.active_cycles < fixed.report.active_cycles,
        "autoscaler spent {} provisioned fabric-cycles, fixed spent {}",
        auto_run.report.active_cycles,
        fixed.report.active_cycles,
    );
    // Scale decisions leave an audit trail in the span stream.
    assert!(tel
        .spans()
        .iter()
        .any(|s| s.kind == SpanKind::Scale));
}
