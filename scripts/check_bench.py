#!/usr/bin/env python3
"""Gate CI on the committed bench baselines.

Usage: check_bench.py <baseline.json> <fresh.json>

Each file is a JSON array of rows written by `util::bench::write_json`
(name, wall_s, sim_cycles, sim_cycles_per_sec, speedup_vs_naive,
items_per_sec). For every row name present in both files, the fresh
run's throughput must be at least 80% of the committed baseline's
(>20% regression fails). Throughput is `items_per_sec` when the
baseline row carries one, `sim_cycles_per_sec` otherwise — both are
wall-clock-derived, so the check tolerates runner noise via the 20%
band rather than exact comparison.

A fresh row whose name is absent from the baseline also fails: a new
bench must land together with its committed baseline row, otherwise it
runs ungated forever.

Bootstrap rows — committed with `wall_s == 0` before any real
measurement exists — are skipped with a notice; the first CI run on a
real machine replaces them via a normal commit of the regenerated
JSON.
"""

import json
import sys


def load(path):
    with open(path) as f:
        rows = json.load(f)
    return {r["name"]: r for r in rows}


def throughput(row):
    ips = row.get("items_per_sec", 0.0)
    return ips if ips > 0 else row.get("sim_cycles_per_sec", 0.0)


def compare(base, fresh):
    """Compare fresh rows against baseline rows.

    Returns (lines, failures, checked): human-readable per-row lines,
    failure messages (empty == gate passes), and the number of rows
    actually throughput-checked.
    """
    lines = []
    failures = []
    checked = 0
    for name, brow in sorted(base.items()):
        if brow.get("wall_s", 0.0) == 0.0:
            lines.append(
                f"  SKIP {name}: bootstrap baseline (no measurement)")
            continue
        frow = fresh.get(name)
        if frow is None:
            failures.append(f"{name}: row missing from fresh run")
            continue
        b, f = throughput(brow), throughput(frow)
        if b <= 0:
            lines.append(
                f"  SKIP {name}: baseline has no throughput figure")
            continue
        checked += 1
        ratio = f / b
        status = "OK  " if ratio >= 0.8 else "FAIL"
        lines.append(f"  {status} {name}: {f:.1f} vs baseline {b:.1f} "
                     f"({ratio:.2f}x)")
        if ratio < 0.8:
            failures.append(
                f"{name}: {ratio:.2f}x of baseline throughput "
                f"(>20% regression)")
    for name in sorted(set(fresh) - set(base)):
        failures.append(
            f"{name}: fresh row has no committed baseline "
            f"(add it to the baseline JSON)")
    return lines, failures, checked


def main():
    if len(sys.argv) != 3:
        sys.exit(f"usage: {sys.argv[0]} <baseline.json> <fresh.json>")
    base = load(sys.argv[1])
    fresh = load(sys.argv[2])
    lines, failures, checked = compare(base, fresh)
    for line in lines:
        print(line)
    print(f"checked {checked} row(s) against {sys.argv[1]}")
    if failures:
        print("bench regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        sys.exit(1)


if __name__ == "__main__":
    main()
