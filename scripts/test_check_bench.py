#!/usr/bin/env python3
"""Unit tests for the check_bench.py skip/compare logic.

Run with: python3 scripts/test_check_bench.py
"""

import unittest

from check_bench import compare


def row(name, wall_s=1.0, ips=0.0, scps=0.0):
    return {
        "name": name,
        "wall_s": wall_s,
        "items_per_sec": ips,
        "sim_cycles_per_sec": scps,
    }


def by_name(rows):
    return {r["name"]: r for r in rows}


class CompareTest(unittest.TestCase):
    def test_within_band_passes(self):
        base = by_name([row("a", ips=100.0)])
        fresh = by_name([row("a", ips=85.0)])
        lines, failures, checked = compare(base, fresh)
        self.assertEqual(failures, [])
        self.assertEqual(checked, 1)
        self.assertTrue(any("OK" in l and "a" in l for l in lines))

    def test_regression_beyond_band_fails(self):
        base = by_name([row("a", ips=100.0)])
        fresh = by_name([row("a", ips=79.0)])
        _, failures, checked = compare(base, fresh)
        self.assertEqual(checked, 1)
        self.assertEqual(len(failures), 1)
        self.assertIn("regression", failures[0])

    def test_bootstrap_baseline_skipped(self):
        base = by_name([row("a", wall_s=0.0, ips=100.0)])
        fresh = by_name([row("a", ips=1.0)])
        lines, failures, checked = compare(base, fresh)
        self.assertEqual(failures, [])
        self.assertEqual(checked, 0)
        self.assertTrue(any("SKIP" in l and "bootstrap" in l
                            for l in lines))

    def test_zero_throughput_baseline_skipped(self):
        base = by_name([row("a")])
        fresh = by_name([row("a", ips=50.0)])
        lines, failures, checked = compare(base, fresh)
        self.assertEqual(failures, [])
        self.assertEqual(checked, 0)
        self.assertTrue(any("no throughput figure" in l for l in lines))

    def test_missing_fresh_row_fails(self):
        base = by_name([row("a", ips=100.0)])
        _, failures, _ = compare(base, {})
        self.assertEqual(len(failures), 1)
        self.assertIn("missing from fresh run", failures[0])

    def test_unknown_fresh_row_fails(self):
        # A bench present only in the fresh run has no committed
        # baseline and must fail the gate, not slip through silently.
        base = by_name([row("a", ips=100.0)])
        fresh = by_name([row("a", ips=100.0), row("b", ips=5.0)])
        _, failures, checked = compare(base, fresh)
        self.assertEqual(checked, 1)
        self.assertEqual(len(failures), 1)
        self.assertIn("b", failures[0])
        self.assertIn("no committed baseline", failures[0])

    def test_sim_cycles_fallback_when_no_items_per_sec(self):
        base = by_name([row("a", scps=1000.0)])
        fresh = by_name([row("a", scps=500.0)])
        _, failures, checked = compare(base, fresh)
        self.assertEqual(checked, 1)
        self.assertEqual(len(failures), 1)

    def test_mixed_file_gates_armed_rows_and_skips_bootstrap(self):
        # One file, both kinds of row: the bootstrap (wall_s == 0)
        # row is skipped, but the armed rows beside it still gate —
        # arming a baseline must never be all-or-nothing per file.
        base = by_name([
            row("boot", wall_s=0.0, ips=100.0),
            row("armed_ok", ips=100.0),
            row("armed_bad", ips=100.0),
        ])
        fresh = by_name([
            row("boot", ips=1.0),
            row("armed_ok", ips=95.0),
            row("armed_bad", ips=10.0),
        ])
        lines, failures, checked = compare(base, fresh)
        self.assertEqual(checked, 2)
        self.assertEqual(len(failures), 1)
        self.assertIn("armed_bad", failures[0])
        self.assertTrue(any("SKIP boot" in l and "bootstrap" in l
                            for l in lines))
        self.assertTrue(any("OK" in l and "armed_ok" in l
                            for l in lines))

    def test_armed_zero_throughput_is_skip_not_crash(self):
        # A row armed with wall_s > 0 but no throughput figure at all
        # (both fields zero) is distinct from a bootstrap row: it is
        # reported as "no throughput figure", never divides by zero,
        # and never gates.
        base = by_name([row("a", wall_s=2.5)])
        fresh = by_name([row("a", ips=50.0)])
        lines, failures, checked = compare(base, fresh)
        self.assertEqual((failures, checked), ([], 0))
        self.assertFalse(any("bootstrap" in l for l in lines))
        self.assertTrue(any("no throughput figure" in l for l in lines))

    def test_unknown_bootstrap_fresh_row_still_fails(self):
        # Even against an all-bootstrap baseline, a fresh-only row is
        # reported: nothing about the baseline's state exempts it.
        base = by_name([row("a", wall_s=0.0)])
        fresh = by_name([row("a", ips=1.0), row("new", ips=1.0)])
        _, failures, _ = compare(base, fresh)
        self.assertEqual(len(failures), 1)
        self.assertIn("new", failures[0])


if __name__ == "__main__":
    unittest.main()
